"""Trainer: jitted sharded train step with selectable gradient-reduction
modes, gradient accumulation, mixed precision, and fault-tolerance hooks.

Gradient-reduction modes (the paper's plugin collectives as first-class
training options):

* ``auto``          — GSPMD inserts the DP all-reduce (supports full
                      FSDP/TP/EP; the production default).
* ``allreduce``     — manual-DP shard_map island; the table-generated
                      ``Communicator.allreduce`` over a selectable
                      transport (``TrainConfig.transport``: "xla" HLOs or
                      "pallas" ring kernels — DESIGN.md §7), making the
                      kernel-level fast path selectable end-to-end.
* ``overlap``       — manual-DP shard_map island; the bucketed
                      communication–computation overlap engine
                      (``core/overlap.py``, DESIGN.md §8): gradients are
                      packed into ``bucket_bytes``-target buckets, each
                      bucket reduced with a non-blocking collective
                      tracked in a fixed-slot RequestPool
                      (``max_inflight``), so later buckets' communication
                      overlaps earlier buckets' completion work.  Rides
                      the same selectable transport as ``allreduce``.
* ``compressed``    — back-compat alias for ``allreduce`` +
                      ``grad_compress="int8-ef"`` (below).
* ``reproducible``  — alias for ``allreduce`` + the engine-level
                      ``deterministic("tree", leaves=microbatches)``
                      parameter (DESIGN.md §12): per-microbatch leaf
                      gradients reduced with the p-invariant canonical
                      tree — bitwise-identical training runs for any
                      power-of-two DP size, any transport (the tree is
                      pure ppermute), for a fixed global leaf count
                      ``M = dp_size * microbatches``.

Orthogonally, ``grad_compress`` selects a payload codec from the engine
registry (``repro.core.compression``, DESIGN.md §10) for the manual
``allreduce``/``overlap``/``reproducible`` modes: every floating-point
gradient reduction carries ``compression(codec, state=err)`` (error
feedback threaded through the op's result / the overlap engine's
RequestPool plan), and the codec composes with whatever transport moves
the bytes — ``xla``, ``pallas`` rings, or the two-level ``hier``
schedule.  Under ``reproducible`` only deterministic-capable codecs are
accepted (quantized-leaf semantics: exact tree accumulation of the
quantized partials — int8-ef / fp8-e4m3; topk's rank-dependent
scatter-add is rejected at construction time).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import operator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    Communicator,
    compression,
    deterministic,
    get_codec,
    op,
    overlap_reduce_tree,
    send_buf,
)
from repro.models import Runtime, loss_and_metrics
from repro.sharding.rules import (
    ShardingProfile,
    batch_specs,
    named_shardings,
    param_specs,
)
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainConfig", "Trainer", "make_train_step"]


@dataclasses.dataclass
class TrainConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    # auto | allreduce | overlap | compressed | reproducible
    grad_reduce: str = "auto"
    microbatches: int = 1  # grad accumulation steps (per device for manual)
    aux_weight: float = 0.01
    # Collective backend for the manual-DP modes' communicator
    # (None -> "xla"; "pallas" -> ring kernels; "hier" -> the two-level
    # hierarchical transport, DESIGN.md §7/§9).
    transport: Optional[str] = None
    # transport="hier" knobs (core/hier.py): ranks per intra group
    # (None -> the balanced sqrt-ish default divisor of the dp size) and
    # the per-level base backends (intra-group / cross-group).
    group_size: Optional[int] = None
    hier_intra: str = "xla"
    hier_inter: str = "xla"
    # grad_reduce="overlap" knobs (core/overlap.py, DESIGN.md §8):
    # target bytes per gradient bucket, fixed-slot in-flight bound, and
    # the per-bucket collective ("allreduce" | "reduce_scatter" — the
    # latter is the bandwidth-optimal RS+AG decomposition).
    bucket_bytes: int = 4 << 20
    max_inflight: int = 2
    overlap_mode: str = "allreduce"
    # Payload codec for the manual allreduce/overlap/reproducible
    # gradient reduction (None = uncompressed; "int8-ef" | "fp8-e4m3" |
    # "topk" | any registered codec name or Codec instance —
    # repro.core.compression, DESIGN.md §10).  Error-feedback state lives
    # in the trainer's `extra` state and is threaded through the engine
    # automatically.  Under grad_reduce="reproducible" only
    # deterministic-capable codecs compose (quantized-leaf semantics,
    # DESIGN.md §12); "topk" raises at construction.
    grad_compress: Optional[str] = None
    # grad_reduce="overlap" only: hand the bucketed reduction to the
    # trace-time planner (core/planner.py, DESIGN.md §13).  "auto" fits
    # the cost model from benchmarks/artifacts/*.json and autotunes
    # transport / bucket_bytes / mode / max_inflight; a Plan instance
    # pins the choices (its knobs override the fields above).  Every
    # planner rewrite is bitwise-neutral — planned and unplanned steps
    # produce identical parameters (tests/test_planner_equivalence.py).
    plan: Any = None
    # grad_reduce="overlap" only: reduction-order determinism mode for
    # the bucketed reduction ("tree" = the p-invariant canonical tree,
    # DESIGN.md §12).  grad_reduce="reproducible" remains the
    # whole-trainer alias; this knob composes determinism with the
    # overlap scheduler (and with the planner — plans never perturb a
    # deterministic reduction's order).
    deterministic: Optional[str] = None

    def __post_init__(self):
        # Back-compat: the pre-codec-registry mode string maps onto the
        # engine path (bitwise-identical math — tests/test_compression.py
        # pins the equivalence against the original helper).
        if self.grad_reduce == "compressed":
            self.grad_reduce = "allreduce"
            if self.grad_compress is None:
                self.grad_compress = "int8-ef"
        # reproducible + codec: only deterministic-capable codecs have
        # defined quantized-leaf semantics under the canonical tree
        # (DESIGN.md §12); topk's scatter-add order is rank-dependent, so
        # the combination is rejected here, at construction time.
        if self.grad_reduce == "reproducible" and self.grad_compress is not None:
            codec = get_codec(self.grad_compress)
            if not codec.supports_deterministic:
                raise ValueError(
                    f"TrainConfig: grad_compress={self.grad_compress!r} does "
                    "not compose with grad_reduce='reproducible': the "
                    "codec's reduction order is not p-invariant (topk's "
                    "scatter-add depends on which rank shipped each "
                    "coordinate).  Use a deterministic-capable codec "
                    "('int8-ef', 'fp8-e4m3') or drop grad_compress."
                )


def _split_microbatches(batch, m):
    return jax.tree.map(
        lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch
    )


def make_train_step(cfg, tcfg: TrainConfig, runtime: Runtime,
                    profile: ShardingProfile, mesh):
    """Returns train_step(params, opt_state, extra_state, batch)."""

    def loss_fn(params, batch):
        return loss_and_metrics(
            params, batch, cfg, runtime, aux_weight=tcfg.aux_weight
        )

    if tcfg.grad_reduce not in ("auto", "allreduce", "overlap",
                                "reproducible"):
        raise ValueError(
            f"TrainConfig.grad_reduce={tcfg.grad_reduce!r}: expected one of "
            "'auto', 'allreduce', 'overlap', 'reproducible' (or the "
            "back-compat alias 'compressed' = allreduce + "
            "grad_compress='int8-ef')"
        )
    # Codec resolution (DESIGN.md §10): eager, so a typo is a
    # construction-time error; only the manual engine modes reduce
    # through the op-spec table where codecs live.
    grad_codec = (
        get_codec(tcfg.grad_compress) if tcfg.grad_compress is not None
        else None
    )
    if grad_codec is not None and tcfg.grad_reduce not in (
        "allreduce", "overlap", "reproducible"
    ):
        raise ValueError(
            f"TrainConfig.grad_compress={tcfg.grad_compress!r} requires "
            f"grad_reduce='allreduce', 'overlap', or 'reproducible' (got "
            f"{tcfg.grad_reduce!r}): compression is an engine-level "
            "parameter of the table-generated reductions"
        )
    if (
        grad_codec is not None
        and tcfg.grad_reduce == "reproducible"
        and not grad_codec.supports_deterministic
    ):
        # Normally caught in TrainConfig.__post_init__; re-checked here
        # for configs mutated after construction.
        raise ValueError(
            f"TrainConfig.grad_compress={tcfg.grad_compress!r} does not "
            "compose with grad_reduce='reproducible' (codec reduction "
            "order is not p-invariant); use 'int8-ef' or 'fp8-e4m3'"
        )
    # Planner / determinism knobs live in the overlap scheduler
    # (DESIGN.md §8/§13): validated eagerly so a misplaced config is a
    # construction-time error rather than a silently-ignored field.
    if tcfg.plan is not None and tcfg.grad_reduce != "overlap":
        raise ValueError(
            f"TrainConfig.plan={tcfg.plan!r} requires "
            f"grad_reduce='overlap' (got {tcfg.grad_reduce!r}): the "
            "planner schedules the bucketed reduction program"
        )
    if tcfg.deterministic is not None and tcfg.grad_reduce != "overlap":
        raise ValueError(
            f"TrainConfig.deterministic={tcfg.deterministic!r} requires "
            f"grad_reduce='overlap' (got {tcfg.grad_reduce!r}); for the "
            "whole-trainer deterministic alias use "
            "grad_reduce='reproducible'"
        )
    if (
        tcfg.deterministic is not None
        and grad_codec is not None
        and not grad_codec.supports_deterministic
    ):
        raise ValueError(
            f"TrainConfig.grad_compress={tcfg.grad_compress!r} does not "
            "compose with deterministic gradient reduction (codec "
            "reduction order is not p-invariant); use 'int8-ef' or "
            "'fp8-e4m3'"
        )

    if tcfg.grad_reduce == "auto":

        def train_step(params, opt_state, extra, batch):
            if tcfg.microbatches > 1:
                mb = _split_microbatches(batch, tcfg.microbatches)

                def acc_fn(carry, b):
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, b
                    )
                    gsum, lsum = carry
                    return (
                        jax.tree.map(jnp.add, gsum, jax.tree.map(
                            lambda x: x.astype(jnp.float32), g)),
                        lsum + l,
                    ), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (gsum, lsum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), mb)
                grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
                loss = lsum / tcfg.microbatches
                metrics = {}
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, batch)
            new_params, new_opt, opt_metrics = adamw_update(
                tcfg.opt, grads, opt_state, cfg.param_dtype
            )
            return new_params, new_opt, extra, loss, {**(metrics or {}), **opt_metrics}

        return train_step

    # ---- manual-DP modes: shard_map island over the dp axes only --------
    dp_axes = profile.dp_axes
    dp_name = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_set = set(dp_axes)

    # Transport resolution (DESIGN.md §7/§9): "hier" with explicit knobs
    # becomes a configured HierTransport instance (two-level reduction:
    # intra-group reduce-scatter -> cross-group allreduce -> intra-group
    # allgather, per-level backends); plain names pass through.
    grad_transport = tcfg.transport
    if grad_transport == "hier" and (
        tcfg.group_size is not None
        or tcfg.hier_intra != "xla"
        or tcfg.hier_inter != "xla"
    ):
        from repro.core import HierTransport

        grad_transport = HierTransport(
            group_size=tcfg.group_size,
            intra=tcfg.hier_intra,
            inter=tcfg.hier_inter,
        )
    elif (
        tcfg.group_size is not None
        or tcfg.hier_intra != "xla"
        or tcfg.hier_inter != "xla"
    ):
        raise ValueError(
            f"TrainConfig.group_size/hier_intra/hier_inter are only "
            f"meaningful with transport='hier' (got "
            f"transport={tcfg.transport!r}, group_size={tcfg.group_size}, "
            f"hier_intra={tcfg.hier_intra!r}, hier_inter={tcfg.hier_inter!r})"
        )

    def microbatch_grads(params, batch):
        """Per-microbatch fp32 leaf grads + losses (shared by the manual
        modes that honor grad accumulation)."""
        mb = _split_microbatches(batch, tcfg.microbatches)

        def one(b):
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
            return jax.tree.map(lambda x: x.astype(jnp.float32), g), l

        return jax.lax.map(one, mb)

    def manual_grads(params, batch, err):
        """Runs inside shard_map (manual over dp): local grads + plugin
        reduction. err=None unless a codec with error feedback is on."""
        if tcfg.grad_reduce in ("allreduce", "overlap"):
            # The table-generated allreduce over the configured transport
            # (DESIGN.md §7): the gradient fast path is a backend choice,
            # not a different training loop.  "overlap" keeps the same
            # loss/grad computation but hands the reduction to the
            # bucketing scheduler (core/overlap.py, DESIGN.md §8).  A
            # grad_compress codec rides either reduction as the engine's
            # compression(...) parameter (DESIGN.md §10).
            if tcfg.microbatches > 1:
                stacked, losses = microbatch_grads(params, batch)
                grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked)
                loss = jnp.mean(losses)
            else:
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, batch)
            comm = Communicator(dp_name, transport=grad_transport)
            inv_p = 1.0 / comm.size()
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

            new_err = None
            if tcfg.grad_reduce == "overlap":
                if grad_codec is not None:
                    grads, new_err = overlap_reduce_tree(
                        comm, grads,
                        bucket_bytes=tcfg.bucket_bytes,
                        max_inflight=tcfg.max_inflight,
                        mode=tcfg.overlap_mode,
                        scale=inv_p,
                        compression=grad_codec,
                        err_state=err,
                        deterministic=tcfg.deterministic,
                        plan=tcfg.plan,
                    )
                else:
                    grads = overlap_reduce_tree(
                        comm, grads,
                        bucket_bytes=tcfg.bucket_bytes,
                        max_inflight=tcfg.max_inflight,
                        mode=tcfg.overlap_mode,
                        scale=inv_p,
                        deterministic=tcfg.deterministic,
                        plan=tcfg.plan,
                    )
            elif grad_codec is not None:
                flat_g, gdef = jax.tree.flatten(grads)
                flat_e = gdef.flatten_up_to(err)

                def reduce_leaf(g, e):
                    # every leaf is float32 here (cast above), so the
                    # codec applies unconditionally
                    r = comm.allreduce(
                        send_buf(g), op(operator.add),
                        compression(grad_codec, state=e),
                    )
                    return r.recv_buf * inv_p, r.compression_state

                out = [reduce_leaf(g, e) for g, e in zip(flat_g, flat_e)]
                grads = jax.tree.unflatten(gdef, [o[0] for o in out])
                new_err = jax.tree.unflatten(gdef, [o[1] for o in out])
            else:
                grads = jax.tree.map(
                    lambda g: comm.allreduce(
                        send_buf(g), op(operator.add)
                    ) * inv_p,
                    grads,
                )
            loss = jax.lax.pmean(loss, dp_name)
            return grads, new_err, loss
        # reproducible: alias for allreduce + the engine-level
        # deterministic("tree", leaves=microbatches) parameter
        # (DESIGN.md §12).  Each microbatch gradient is one canonical
        # leaf (global leaf index = rank*microbatches + i, the global
        # data order), so the mean is bitwise independent of the DP size
        # for a fixed global leaf count M = p*microbatches — under every
        # transport (the tree is pure ppermute) and, with a quantized
        # codec, over the quantized leaf partials (exact accumulation).
        stacked, losses = microbatch_grads(params, batch)
        comm = Communicator(dp_name, transport=grad_transport)
        denom = tcfg.microbatches * comm.size()
        det = deterministic("tree", leaves=tcfg.microbatches)

        new_err = None
        if grad_codec is not None:
            flat_g, gdef = jax.tree.flatten(stacked)
            flat_e = gdef.flatten_up_to(err)

            def reduce_leaf_c(g, e):
                r = comm.allreduce(
                    send_buf(g), op(operator.add), det,
                    compression(grad_codec, state=e),
                )
                return r.recv_buf / denom, r.compression_state

            out = [reduce_leaf_c(g, e) for g, e in zip(flat_g, flat_e)]
            grads = jax.tree.unflatten(gdef, [o[0] for o in out])
            new_err = jax.tree.unflatten(gdef, [o[1] for o in out])
        else:
            grads = jax.tree.map(
                lambda g: comm.allreduce(
                    send_buf(g), op(operator.add), det
                ) / denom,
                stacked,
            )
        loss = jax.lax.pmean(jnp.mean(losses), dp_name)
        return grads, new_err, loss

    def train_step(params, opt_state, extra, batch):
        bspec = jax.tree.map(lambda _: P(profile.dp), batch)
        pspec = jax.tree.map(lambda _: P(), params)
        if grad_codec is not None:
            espec = jax.tree.map(lambda _: P(profile.dp), extra)

            def body(p_, b_, e_):
                # strip the leading dp dim of the error state inside
                e_loc = jax.tree.map(lambda x: x[0], e_)
                g, ne, l = manual_grads(p_, b_, e_loc)
                ne = jax.tree.map(lambda x: x[None], ne)
                return g, ne, l[None]

            grads, new_extra, loss = jax.shard_map(
                body,
                mesh=mesh,
                in_specs=(pspec, bspec, espec),
                out_specs=(pspec, espec, P(profile.dp)),
                axis_names=dp_set,
                check_vma=False,
            )(params, batch, extra)
            loss = jnp.mean(loss)
        else:
            def body(p_, b_):
                g, _, l = manual_grads(p_, b_, None)
                return g, l[None]

            grads, loss = jax.shard_map(
                body,
                mesh=mesh,
                in_specs=(pspec, bspec),
                out_specs=(pspec, P(profile.dp)),
                axis_names=dp_set,
                check_vma=False,
            )(params, batch)
            new_extra = extra
            loss = jnp.mean(loss)
        new_params, new_opt, opt_metrics = adamw_update(
            tcfg.opt, grads, opt_state, cfg.param_dtype
        )
        return new_params, new_opt, new_extra, loss, opt_metrics

    return train_step


class Trainer:
    """Host-side orchestration: sharded init, jitted step, checkpoint and
    fault-tolerance integration (see train.fault_tolerance)."""

    def __init__(self, cfg, mesh, profile: ShardingProfile,
                 tcfg: Optional[TrainConfig] = None, runtime=None):
        self.cfg = cfg
        self.mesh = mesh
        self.profile = profile
        self.tcfg = tcfg or TrainConfig()
        self.runtime = runtime or Runtime(
            mesh=mesh,
            tp_axis=profile.tp_axis or "model",
            batch_spec_axes=profile.dp,
            force_moe_mode=profile.moe_mode if profile.moe_mode != "ep_alltoall" else None,
        )
        self._step_fn = None

    # -- state ----------------------------------------------------------------
    def dp_size(self) -> int:
        """Data-parallel world size on this trainer's mesh (the leading
        dimension of the EF ``extra`` state)."""
        return int(
            np.prod([self.mesh.shape[a] for a in self.profile.dp_axes])
        )

    def _state_specs(self, key=None, ep_size: int = 1):
        """(init closure, param specs, opt specs) for this mesh — shared
        by :meth:`init_state` and :meth:`restore_state` so restore
        places leaves with exactly the shardings init would have used
        (the elastic reshard onto the current mesh)."""
        from repro.models import init_params

        def init():
            params = init_params(
                self.cfg, key if key is not None else jax.random.PRNGKey(0),
                ep_size,
            )
            return params, adamw_init(params)

        params_shape = jax.eval_shape(init)
        pspecs = param_specs(
            params_shape[0], self.cfg, self.profile, self.mesh
        )
        ospecs = {
            "step": P(),
            "master": pspecs,
            "mu": pspecs,
            "nu": pspecs,
        }
        return init, pspecs, ospecs

    def init_state(self, key, ep_size: int = 1):
        init, pspecs, ospecs = self._state_specs(key, ep_size)
        out_shardings = (
            named_shardings(self.mesh, pspecs),
            named_shardings(self.mesh, ospecs),
        )
        params, opt_state = jax.jit(init, out_shardings=out_shardings)()
        extra = None
        if self.tcfg.grad_compress is not None:
            # Error-feedback residual, one slot per rank — and, under
            # reproducible, per canonical leaf (the residual follows the
            # leaf partitioning, so it is p-invariant too).
            lead = (self.dp_size(),)
            if self.tcfg.grad_reduce == "reproducible":
                lead = (self.dp_size(), self.tcfg.microbatches)
            extra = jax.tree.map(
                lambda p: jnp.zeros(lead + p.shape, jnp.float32), params
            )
        self.param_specs = pspecs
        self.opt_specs = ospecs
        return params, opt_state, extra

    # -- checkpoint / elastic restore (DESIGN.md §15) --------------------------
    def save_state(self, ckpt, step: int, state, *, async_: bool = False,
                   extra_meta: Optional[Dict] = None):
        """Checkpoint ``(params, opt, extra)`` with the reshard metadata
        an elastic restore needs: the saving world's dp size and
        microbatch count (the EF state's ``(dp, mb)`` provenance) ride
        in the manifest, so :meth:`restore_state` on a different-sized
        mesh knows how to fold the residuals."""
        params, opt_state, extra = state
        tree = {"params": params, "opt": opt_state}
        if extra is not None:
            tree["extra"] = extra
        meta = {
            "dp_size": self.dp_size(),
            "microbatches": self.tcfg.microbatches,
            "grad_reduce": self.tcfg.grad_reduce,
        }
        meta.update(extra_meta or {})
        ckpt.save(step, tree, extra_meta=meta, async_=async_)

    def restore_state(self, ckpt, step: Optional[int] = None):
        """Restore a :meth:`save_state` snapshot onto *this* trainer's
        mesh (the elastic-reshard path of the ULFM recovery loop).

        Params/opt are re-placed with the current mesh's shardings;
        error-feedback ``extra`` state is resharded to this mesh's
        ``(dp, mb)`` shape via :func:`repro.core.compression
        .reshard_error_feedback` — exact leaf-order-preserving reshape
        under ``reproducible`` (so ``deterministic("tree")`` runs stay
        bitwise across the resize, which requires ``microbatches`` to be
        scaled to keep the global leaf count: see
        :func:`repro.core.reproducible.elastic_leaves`), additive
        per-rank fold otherwise.  Returns ``(params, opt, extra)``.
        """
        from repro.core.compression import reshard_error_feedback
        from repro.core.errors import KampingError

        tree, meta = ckpt.restore(step)
        _, pspecs, ospecs = self._state_specs()
        params = jax.device_put(
            tree["params"], named_shardings(self.mesh, pspecs)
        )
        opt_state = jax.device_put(
            tree["opt"], named_shardings(self.mesh, ospecs)
        )
        extra = tree.get("extra")
        if extra is not None:
            saved = meta.get("extra", {})
            old_dp = int(saved.get("dp_size") or self.dp_size())
            leaf_stacked = (
                saved.get("grad_reduce", self.tcfg.grad_reduce)
                == "reproducible"
            )
            extra = reshard_error_feedback(
                extra, old_dp, self.dp_size(), leaf_stacked=leaf_stacked
            )
            if leaf_stacked:
                mb = jax.tree.leaves(extra)[0].shape[1]
                if mb != self.tcfg.microbatches:
                    raise KampingError(
                        f"restore_state: resharded EF state carries {mb} "
                        f"leaves/rank but TrainConfig.microbatches is "
                        f"{self.tcfg.microbatches} — scale microbatches "
                        "to preserve the global leaf count "
                        "(core.reproducible.elastic_leaves)"
                    )
            extra = jax.tree.map(jnp.asarray, extra)
        if self.tcfg.grad_compress is None:
            extra = None
        self.param_specs = pspecs
        self.opt_specs = ospecs
        return params, opt_state, extra

    def abort_inflight(self) -> int:
        """ULFM drain hook (DESIGN.md §15).  The jitted step's
        RequestPools live at trace time — their buckets are values
        inside the staged program, so discarding the failed step's
        *outputs* (the runner replays from the last checkpoint) is the
        drain; there is never host-side in-flight state to cancel."""
        return 0

    # -- step -----------------------------------------------------------------
    def step_fn(self):
        if self._step_fn is None:
            fn = make_train_step(
                self.cfg, self.tcfg, self.runtime, self.profile, self.mesh
            )
            self._step_fn = jax.jit(fn, donate_argnums=(0, 1, 2))
        return self._step_fn

    def place_batch(self, batch):
        specs = batch_specs(self.profile, batch)
        return jax.device_put(
            batch, named_shardings(self.mesh, specs)
        )

    def run(self, state, data_iter, steps: int, log_every: int = 10,
            health_check: Optional[Callable] = None):
        params, opt_state, extra = state
        step = self.step_fn()
        history = []
        for i in range(steps):
            if health_check is not None:
                health_check()
            batch = self.place_batch(next(data_iter))
            t0 = time.perf_counter()
            params, opt_state, extra, loss, metrics = step(
                params, opt_state, extra, batch
            )
            if i % log_every == 0 or i == steps - 1:
                l = float(loss)
                history.append((i, l, time.perf_counter() - t0))
        return (params, opt_state, extra), history
