"""ULFM-style fault-tolerant training loop (paper §V-B, Fig. 12) plus
straggler mitigation.

The control flow mirrors the paper's example verbatim — exceptions instead
of return codes, ``revoke()``, ``shrink()`` — adapted to the TPU failure
model: a failure kills a host/slice, recovery = rebuild a (possibly
smaller) mesh from survivors + restore & reshard the latest checkpoint.

::

    try:
        step(...)
    except DeviceFailureDetected:
        if not world.is_revoked():
            world.revoke()
        world = world.shrink(failed)
        mesh  = world.mesh()          # smaller but rectangular
        state = ckpt.restore(shardings_on(mesh))   # elastic reshard
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core.ulfm import DeviceFailureDetected, WorldComm
from repro.checkpoint.manager import CheckpointManager

__all__ = ["FaultTolerantRunner", "StragglerWatchdog"]


class StragglerWatchdog:
    """Step-time EMA monitor: flags steps slower than ``threshold`` x the
    running mean — the hook where a production deployment triggers
    rebalancing / preemptive checkpointing for slow hosts."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.flagged.append(step)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclasses.dataclass
class FTEvent:
    step: int
    kind: str  # "failure" | "shrink" | "restore" | "straggler"
    detail: str = ""


class FaultTolerantRunner:
    """Wraps a trainer-factory so training survives injected failures.

    ``make_trainer(world) -> (trainer, state)`` builds a trainer + state on
    the world's current mesh — called initially and after every shrink
    (restoring from the latest checkpoint with the new mesh's shardings).
    """

    def __init__(
        self,
        world: WorldComm,
        ckpt: CheckpointManager,
        make_trainer: Callable,
        checkpoint_every: int = 10,
    ):
        self.world = world
        self.ckpt = ckpt
        self.make_trainer = make_trainer
        self.checkpoint_every = checkpoint_every
        self.events: List[FTEvent] = []
        self.watchdog = StragglerWatchdog()

    def run(self, data_iter, total_steps: int):
        trainer, state = self.make_trainer(self.world, None)
        step = 0
        losses = []
        while step < total_steps:
            try:
                self.world.check_health()
                batch = trainer.place_batch(next(data_iter))
                t0 = time.perf_counter()
                params, opt_state, extra = state
                params, opt_state, extra, loss, _ = trainer.step_fn()(
                    params, opt_state, extra, batch
                )
                state = (params, opt_state, extra)
                dt = time.perf_counter() - t0
                if self.watchdog.observe(step, dt):
                    self.events.append(FTEvent(step, "straggler", f"{dt:.3f}s"))
                losses.append(float(loss))
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(
                        step,
                        {"params": params, "opt": opt_state},
                        extra_meta={"generation": self.world.generation},
                        async_=True,
                    )
            except DeviceFailureDetected as e:
                # — paper Fig. 12, verbatim control flow —
                self.events.append(FTEvent(step, "failure", str(e.failed)))
                if not self.world.is_revoked():
                    self.world.revoke()
                self.world = self.world.shrink(e.failed)
                self.events.append(
                    FTEvent(step, "shrink", f"{self.world.size()} devices")
                )
                restore_step = self.ckpt.latest_step()
                trainer, state = self.make_trainer(self.world, restore_step)
                step = restore_step or 0
                self.events.append(FTEvent(step, "restore", f"step {step}"))
        self.ckpt.wait()
        return state, losses
