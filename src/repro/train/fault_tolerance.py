"""ULFM-style fault-tolerant training loop through the engine
(paper §V-B, Fig. 12; DESIGN.md §15) plus straggler mitigation.

The control flow mirrors the paper's example verbatim — exceptions
instead of return codes, ``revoke()``, ``shrink()`` — adapted to the TPU
failure model (a failure kills a host/slice) and routed through the
engine rather than beside it.  Recovery is::

    try:
        dispatch step; health-check; commit step
    except DeviceFailureDetected:
        if not world.is_revoked():
            world.revoke()
        trainer.abort_inflight()        # drain RequestPool buckets (§8)
        ckpt.wait()                     # flush the async writer (§15)
        world = world.shrink(failed)    # survivors-as-split Communicator,
                                        # re-derived hier topology (§9/§13)
        trainer, state = make_trainer(world, ckpt.latest_step())
                                        # restore + reshard: EF residuals
                                        # to the new (dp, mb) (§10/§12)
        losses = losses[:restore_step]  # replayed steps are recomputed
        data   = make_data(restore_step, world)   # rewind, leaf order kept

State commit is atomic at step granularity: a step whose buckets were
in flight when the failure hit is *discarded* (its reductions never
completed on the dead ranks) and replayed from the last durable
checkpoint — which, with the §15 carry-over rules (EF residuals
resharded by :func:`repro.core.compression.reshard_error_feedback`,
global leaf order preserved by the data rewind), makes the recovered
run bitwise identical to a clean restart on the shrunken world
(``tests/test_elastic.py``).

Three failure points are health-checked (``core.ulfm.FAILURE_POINTS``):
between steps, mid-collective (after dispatch, before commit), and
mid-checkpoint (after an async save is enqueued).  The data source is a
factory ``make_data(start_step, world) -> iterator`` so recovery can
rewind to the restore step with the survivors' leaf assignment; a plain
iterator is accepted for failure-free runs but cannot be rewound.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

from repro.core.errors import KampingError
from repro.core.ulfm import DeviceFailureDetected, WorldComm
from repro.checkpoint.manager import CheckpointManager

__all__ = ["FaultTolerantRunner", "StragglerWatchdog", "FTEvent"]


class StragglerWatchdog:
    """Step-time EMA monitor: flags steps slower than ``threshold`` x the
    running mean — the hook where a production deployment triggers
    rebalancing / preemptive checkpointing for slow hosts."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.flagged.append(step)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclasses.dataclass
class FTEvent:
    step: int
    kind: str  # "failure" | "drain" | "shrink" | "restore" | "straggler"
    detail: str = ""


class FaultTolerantRunner:
    """Wraps a trainer factory so training survives injected failures.

    ``make_trainer(world, restore_step) -> (trainer, state)`` builds a
    trainer + state on the world's current mesh — called initially
    (``restore_step=None`` → fresh init) and after every shrink
    (``restore_step`` = the latest durable checkpoint, which the factory
    restores with the new mesh's shardings and the §15 reshard rules,
    e.g. via ``Trainer.restore_state``).

    The trainer protocol, duck-typed so the LM :class:`~repro.train
    .trainer.Trainer` and lightweight test harnesses both fit:

    * required — ``place_batch(batch)``, ``step_fn() -> f(params, opt,
      extra, batch) -> (params, opt, extra, loss, metrics)``;
    * optional — ``begin_step(state, batch) -> handle`` +
      ``complete_step(handle) -> outputs`` (dispatch/commit split: the
      mid-collective health check runs between them, while the step's
      RequestPool buckets are in flight); ``abort_inflight() -> int``
      (the §15 drain verb — cancel in-flight buckets, return the
      count); ``save_state(ckpt, step, state, async_=..,
      extra_meta=..)`` (checkpoint including EF ``extra`` state and
      reshard metadata).
    """

    def __init__(
        self,
        world: WorldComm,
        ckpt: CheckpointManager,
        make_trainer: Callable,
        checkpoint_every: int = 10,
        save_async: bool = True,
    ):
        self.world = world
        self.ckpt = ckpt
        self.make_trainer = make_trainer
        self.checkpoint_every = checkpoint_every
        self.save_async = save_async
        self.events: List[FTEvent] = []
        self.watchdog = StragglerWatchdog()

    # -- data ------------------------------------------------------------------
    def _data_iter(self, data, start_step: int):
        if callable(data):
            return data(start_step, self.world)
        if start_step:
            raise KampingError(
                "FaultTolerantRunner: recovery needs a rewindable data "
                "source — pass make_data(start_step, world) -> iterator "
                "instead of a bare iterator"
            )
        return iter(data)

    # -- checkpoint ------------------------------------------------------------
    def _save(self, trainer, state, step: int):
        meta = {
            "generation": self.world.generation,
            "world_size": self.world.size(),
        }
        saver = getattr(trainer, "save_state", None)
        if saver is not None:
            saver(self.ckpt, step, state,
                  async_=self.save_async, extra_meta=meta)
            return
        params, opt_state, extra = state
        tree = {"params": params, "opt": opt_state}
        if extra is not None:
            tree["extra"] = extra
        self.ckpt.save(step, tree, extra_meta=meta, async_=self.save_async)

    # -- recovery (paper Fig. 12, engine-routed) -------------------------------
    def _recover(self, e: DeviceFailureDetected, data, step: int,
                 losses: List[float], trainer):
        self.events.append(FTEvent(step, "failure", str(e.failed)))
        if not self.world.is_revoked():
            self.world.revoke()
        # Drain: in-flight RequestPool buckets are garbage (§15 — their
        # reductions never completed on the dead ranks); cancel them so
        # the pool is reusable for the replayed step.
        drained = 0
        aborter = getattr(trainer, "abort_inflight", None)
        if aborter is not None:
            drained = int(aborter() or 0)
        self.events.append(
            FTEvent(step, "drain", f"{drained} in-flight buckets aborted")
        )
        # Flush the async writer: publication is atomic, so after wait()
        # every enqueued snapshot is durable and latest_step() (valid
        # snapshots only) is exactly the recovery point.
        try:
            self.ckpt.wait()
        except Exception as werr:  # a failed save: fall back further
            self.events.append(FTEvent(step, "ckpt-error", repr(werr)))
        self.world = self.world.shrink(e.failed)
        self.events.append(
            FTEvent(step, "shrink",
                    f"{self.world.size()} devices "
                    f"(generation {self.world.generation})")
        )
        restore_step = self.ckpt.latest_step()
        trainer, state = self.make_trainer(self.world, restore_step)
        step = restore_step or 0
        # Replayed steps are recomputed: drop their stale losses too
        # (keeping them double-counts every step after the checkpoint).
        del losses[step:]
        it = self._data_iter(data, step)
        self.events.append(FTEvent(step, "restore", f"step {step}"))
        return trainer, state, it, step

    # -- loop ------------------------------------------------------------------
    def run(self, data, total_steps: int):
        """Train for ``total_steps``, surviving failures at any of the
        three injection points.  ``data`` is a ``make_data(start_step,
        world)`` factory (preferred) or a plain iterator.  Returns
        ``(state, losses)`` with exactly one loss per step — replayed
        steps appear once, with their replayed values."""
        trainer, state = self.make_trainer(self.world, None)
        it = self._data_iter(data, 0)
        step = 0
        losses: List[float] = []
        while step < total_steps:
            try:
                self.world.check_health("step", step=step)
                batch = trainer.place_batch(next(it))
                t0 = time.perf_counter()
                # Dispatch / commit split: between the two, the step's
                # buckets are in flight — the mid-collective window.
                begin = getattr(trainer, "begin_step", None)
                if begin is not None:
                    handle = begin(state, batch)
                    self.world.check_health("collective", step=step)
                    out = trainer.complete_step(handle)
                else:
                    params, opt_state, extra = state
                    out = trainer.step_fn()(params, opt_state, extra, batch)
                    self.world.check_health("collective", step=step)
                params, opt_state, extra, loss, _ = out
                state = (params, opt_state, extra)
                dt = time.perf_counter() - t0
                if self.watchdog.observe(step, dt):
                    self.events.append(FTEvent(step, "straggler", f"{dt:.3f}s"))
                losses.append(float(loss))
                step += 1
                if step % self.checkpoint_every == 0:
                    self._save(trainer, state, step)
                    self.world.check_health("checkpoint", step=step)
            except DeviceFailureDetected as e:
                trainer, state, it, step = self._recover(
                    e, data, step, losses, trainer
                )
        self.ckpt.wait()
        return state, losses
