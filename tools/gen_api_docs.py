#!/usr/bin/env python
"""Generate API.md from the op-spec table (core/opspec.py).

"Every public collective is defined via the op-spec table" is a testable
property of this codebase (tests/test_opspec.py) — which makes the API
reference *derivable*: this script walks ``repro.core.OP_TABLE`` (core
rows plus every plugin row registered at import time) and emits one
section per collective with its named parameters, count-inference rule,
capacity policy, and non-blocking ``i*`` variant.

Usage:
    PYTHONPATH=src python tools/gen_api_docs.py            # (re)write API.md
    PYTHONPATH=src python tools/gen_api_docs.py --check    # CI freshness gate

``--check`` exits non-zero if API.md is missing or stale (the CI docs job
and tests/test_api_docs.py both run it), so the reference can never drift
from the table that defines the surface.
"""
from __future__ import annotations

import inspect
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)

import repro.core  # noqa: E402  (imports register core + plugin specs)
from repro.core import OP_TABLE  # noqa: E402
from repro.core.compression import available_codecs  # noqa: E402
from repro.core.opspec import OP_OWNERS  # noqa: E402
from repro.core.params import ParamKind as K  # noqa: E402

REPO_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.normpath(os.path.join(REPO_ROOT, "API.md"))

HEADER = """\
# API reference — table-generated collectives

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with:  PYTHONPATH=src python tools/gen_api_docs.py
     CI verifies freshness with the --check flag. -->

Every collective below is one row of the declarative op-spec table
(`src/repro/core/opspec.py`, DESIGN.md §3): the row names the parameter
interface and count-inference behaviour, a small `lower` function stages
the data movement, and the shared engine supplies parameter collection,
capacity policies (DESIGN.md §2), leveled assertions, `Result` packing,
and the auto-generated non-blocking `i*` variants (paper §III-E).  This
file is *generated from that table*, so it cannot drift from the code.

Calls take named parameter objects from `repro.core` (`send_buf(x)`,
`send_counts(c)`, `recv_counts_out()`, …) in any order; see
`examples/quickstart.py` for the progression from one-liner to fully
explicit calls.

**Engine-level parameters** accepted by every row:

* `transport("xla" | "pallas" | "hier" | <registered>)` — the collective
  backend moving the bytes (DESIGN.md §7/§9).  Resolution: per-call
  parameter > communicator default (`Communicator(axis, transport=...)`)
  > `"xla"`.  `"hier"` is the composite two-level transport
  (`repro.core.hier.HierTransport`): intra-group reduce-scatter →
  cross-group allreduce → intra-group allgather for reductions, the
  two-hop exchange for `all_to_all`, with per-level base backends
  (`HierTransport(group_size=..., intra=..., inter=...)`).
* `compression({codecs})` — the payload codec for *sum
  reductions* (DESIGN.md §10), accepted by the reduction rows
  (`allreduce`, `reduce`, `reduce_scatter`) and registered via
  `repro.core.compression.register_codec`.  Resolution: per-call
  parameter > communicator default
  (`Communicator(axis, compression=...)`; skips integer payloads) >
  uncompressed; `compression(None)` disables a default.  Error-feedback
  state passed as `compression(name, state=err)` returns on the result
  as `compression_state`.  Codecs compose with every transport (the
  codec encodes once; xla / pallas / hier move the exact accumulator —
  quantize-once / dequantize-once at the hier boundary) and with
  `comm.split()` groups (the scale exchange is group-relative).
* `deterministic("tree", leaves=m)` — p-invariant reduction order for
  the reduction rows (DESIGN.md §12, paper §V-C): the payload's leading
  axis holds `m` local leaves and the reduction is evaluated as the
  canonical perfect binary tree over the *global* leaf sequence
  (`rank * m + i`), so the result is bitwise identical for every
  power-of-two p that partitions the same leaves.  Resolution: per-call
  parameter > communicator default
  (`Communicator(axis, deterministic="tree")`) > off;
  `deterministic(None)` disables a default.  The tree bypasses the
  transport's reduction primitive entirely (pure `ppermute` hops), so
  the bits are also invariant across `transport(...)` backends and
  group-relative under `comm.split()`.  Composes with quantized
  `compression(...)` codecs (the exact accumulator is tree-reduced;
  `topk` raises — its scatter-add order is not p-invariant).
* `plan("auto" | Plan(...))` — hand the *transport* choice to the
  cost-model planner (DESIGN.md §13): `"auto"` fits
  `repro.core.CostModel` from `benchmarks/artifacts/*.json` and picks
  the measured-fastest backend for the row's payload size; a
  `Plan(transport=...)` pins it.  The plan only speaks when nothing was
  chosen explicitly — no per-call `transport(...)`, no communicator
  default, and no plugin routing — so it can never override a user or a
  spec, and every choice is bitwise-neutral by the §7 transport
  contract.  Resolution: per-call parameter > communicator default
  (`Communicator(axis, plan=...)`) > off.  The same object drives the
  bucketed-overlap scheduler (`overlap_reduce_tree(..., plan=...)`,
  `TrainConfig(plan=...)`), where it additionally autotunes bucket
  bytes / per-bucket collective / in-flight bound and applies the IR
  rewrite rules (gated bitwise by tests/test_planner_equivalence.py).

Non-blocking variants return a `NonBlockingResult`; bulk completion goes
through `RequestPool` (`waitall` / `testany` / `collect`), the substrate
of the gradient-overlap engine (`repro.core.overlap`, DESIGN.md §8).
"""

GROUPS_SECTION = """\
---

# Process groups (`comm.split`) — DESIGN.md §9

Groups are a property of the **communicator**, not of any one op: every
row below runs group-scoped on a split communicator with no per-op
changes (`size()` is the group size, so count inference, capacity
policies, and bucket layouts follow automatically; `root`, `dest`, and
`perm=` indices are group-relative).

* `comm.split(color, key=None)` — partition by color
  (cf. `MPI_Comm_split`).  `color`/`key` are indexed by this
  communicator's rank: a sequence of length `size()` or a rank->value
  callable.  Members are ordered by `(key, rank)` (stable).  Colors
  must be **static** — static colors become static groups at trace
  time, lowered to `axis_index_groups` (the zero-overhead rule); traced
  colors raise a trace-time `KampingError`.  Groups must be equally
  sized (SPMD shapes are static; no `MPI_UNDEFINED` opt-out).  Splits
  compose: splitting a split communicator partitions within each group.
* `comm.split_by(block=g)` — contiguous blocks of `g` ranks
  (color = `rank // g`); `comm.split_by(stride=g)` — equal
  `rank % g` across blocks (the cross-block "peer" communicator).
* Topology queries: `rank()` / `size()` are group-relative;
  `global_rank()` / `world_size()` address the underlying axis;
  `group_id()` / `num_groups` identify the group structure.
* Transports: `xla` lowers membership to `axis_index_groups` (with a
  transparent emulation where the running JAX lacks the grouped rule —
  e.g. the vmap-as-SPMD interpreter); `pallas` ring-reindexes each
  group into its own ring; `hier` splits further (two-level schedule
  inside each group).  The per-device TPU RDMA ring kernels reject
  split communicators with a trace-time error.
"""


FT_SECTION_HEADER = """\
---

# Fault tolerance & elastic checkpointing — DESIGN.md §15

ULFM-style recovery (paper §V-B, Fig. 12) routed through the engine:
failures surface as exceptions, `WorldComm.shrink` hands out
survivor-scoped §9 communicators with a re-derived §13 hier topology,
checkpoints are async + per-host sharded with atomic publication, and
error-feedback residuals reshard across the resize
(`repro.core.compression.reshard_error_feedback`).  The member tables
below are **introspected from the live classes** at generation time, so
this section is gated by `--check` exactly like the op-spec rows.
"""


def _summary(obj) -> str:
    """First sentence of the first docstring paragraph, table-safe."""
    doc = inspect.getdoc(obj) or ""
    if not doc:
        return ""
    para = " ".join(doc.strip().split("\n\n")[0].split())
    dot = para.find(". ")
    s = para if dot < 0 else para[: dot + 1]
    return s.replace("|", "\\|")


def _ctor_sig(cls) -> str:
    try:
        sig = str(inspect.signature(cls.__init__))
    except (TypeError, ValueError):
        return "(...)"
    # drop the leading `self`
    inner = sig[1:-1].split(", ")
    return "(" + ", ".join(p for p in inner if p != "self") + ")"


def _ft_section(cls) -> str:
    """One markdown section per fault-tolerance class: constructor
    signature, class summary, and a member table (public methods and
    properties in definition order, each with its first docstring
    sentence).  Introspected, so it cannot drift."""
    mod = cls.__module__.replace("repro.", "repro/").replace(".", "/")
    lines = [
        f"## `{cls.__name__}{_ctor_sig(cls)}`",
        "",
        f"{_summary(cls)}  (`src/{mod}.py`)",
        "",
        "| member | |",
        "|---|---|",
    ]
    for name, member in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(member, property):
            lines.append(f"| `.{name}` (property) | {_summary(member.fget)} |")
        elif callable(member):
            try:
                sig = str(inspect.signature(member)).replace("|", "\\|")
            except (TypeError, ValueError):
                sig = "(...)"
            lines.append(f"| `{name}{sig}` | {_summary(member)} |")
    lines.append("")
    return "\n".join(lines)


def _kind_name(k) -> str:
    return k.value


def _fmt_required(spec) -> str:
    parts = []
    for r in spec.required:
        if isinstance(r, tuple):
            parts.append(" \\| ".join(f"`{_kind_name(k)}`" for k in r))
        else:
            parts.append(f"`{_kind_name(r)}`")
    return ", ".join(parts) if parts else "—"


def _fmt_accepted(spec) -> str:
    names = [f"`{_kind_name(k)}`" for k in spec.accepted]
    names.append("`transport`")  # engine-level: every row accepts it
    if spec.compressible:
        names.append("`compression`")  # engine-level: reduction rows
    if spec.deterministic:
        names.append("`deterministic`")  # engine-level: reduction rows
    return ", ".join(names)


def _count_inference(spec) -> str:
    """The row's count-inference rule, derived from its parameter kinds
    and layout (the regimes implemented by the shared lowerings)."""
    acc = set(spec.accepted)
    rules = []
    if K.RECV_COUNTS in acc and spec.bucketed:
        rules.append(
            "`recv_counts_out()` — inferred with one staged counts "
            "transpose (an `all_to_all` of `send_counts`, riding the op's "
            "own transport/route); a static NumPy `send_counts` resolves "
            "at trace time with nothing staged"
        )
    elif K.RECV_COUNTS in acc:
        rules.append(
            "`recv_counts_out()` — static `send_count` (or a static "
            "per-rank `recv_counts` input) resolves to compile-time "
            "constants with nothing staged (exact/ragged concatenation); "
            "a traced `send_count` stages one scalar-count all-gather and "
            "switches the payload to the padded `i*cap` layout"
        )
    if K.RECV_COUNT in acc:
        rules.append(
            "`recv_count_out()` — this rank's entry of `send_counts`: a "
            "trace-time lookup when static, one staged broadcast from "
            "`root` when traced"
        )
    if K.RECV_DISPLS in acc or K.SEND_DISPLS in acc:
        rules.append(
            "displacements (`*_displs_out()`) — always derived locally "
            "(exclusive prefix sums / capacity strides), never staged "
            "communication"
        )
    if not rules:
        return (
            "counts are implied by static buffer shapes — nothing is "
            "inferred and nothing is staged (the zero-overhead path)."
        )
    return "; ".join(rules) + "."


def _capacity_policy(spec) -> str:
    if spec.bucketed:
        txt = (
            "bucketed `(p, cap, ...)` send layout; `recv_buf(policy)` "
            "selects the capacity policy — `resize_to_fit` (default), "
            "`grow_only(c)` (static bound, NORMAL-level overflow "
            "assertion on shrink), `no_resize` (zero-overhead fast path) "
            "— see DESIGN.md §2."
        )
        if spec.bucket_hint:
            txt += f"  {spec.bucket_hint}"
        return txt
    return (
        "not bucketed — capacities are the buffer's static shape; "
        "`send_count`/`recv_counts` (where accepted) mark the valid "
        "prefix."
    )


def _section(spec) -> str:
    lines = [f"## `{spec.name}`", ""]
    doc = (spec.doc or "").strip()
    if doc:
        lines += [doc, ""]
    lines += [
        "| | |",
        "|---|---|",
        f"| required | {_fmt_required(spec)} |",
        f"| accepted | {_fmt_accepted(spec)} |",
    ]
    owner = OP_OWNERS[spec.name]
    if owner != "Communicator":
        lines.append(f"| plugin | `{owner}` |")
    if spec.in_place_ignored:
        ignored = ", ".join(f"`{_kind_name(k)}`" for k in spec.in_place_ignored)
        lines.append(
            f"| in-place | {ignored} rejected when `send_recv_buf` is "
            "passed (would be ignored) |"
        )
    if spec.kw_accepted:
        kws = ", ".join(f"`{k}=`" for k in spec.kw_accepted)
        lines.append(f"| keywords | {kws} |")
    if spec.transport_attr:
        lines.append(
            f"| routing | op-level override `{spec.transport_attr}` "
            "(wins over the `transport(...)` backend for the dense "
            "exchange) |"
        )
    nb = (
        f"`i{spec.name}(...)` → `NonBlockingResult`"
        if spec.nonblocking
        else "none (bulk-synchronous by construction)"
    )
    lines.append(f"| non-blocking | {nb} |")
    if spec.compressible:
        lines.append(
            "| compression | sum payloads accept `compression(...)` "
            "codecs (engine-level; DESIGN.md §10); "
            "`compression(name, state=err)` returns the new residual as "
            "the result's `compression_state` |"
        )
    if spec.deterministic:
        lines.append(
            "| deterministic | accepts `deterministic(\"tree\", "
            "leaves=m)` (engine-level; DESIGN.md §12): the canonical "
            "perfect-binary-tree order over the global leaf sequence, "
            "bitwise invariant across p, transports, and `comm.split()` "
            "groups |"
        )
    if spec.heavy_count_check:
        lines.append(
            "| HEAVY assertion | global sent == received, verified over "
            "the axis (one counts transpose + two psums; staged only at "
            "`AssertionLevel.HEAVY`) |"
        )
    lines += [
        "",
        f"**Count inference.** {_count_inference(spec)}",
        "",
        f"**Capacity.** {_capacity_policy(spec)}",
        "",
    ]
    return "\n".join(lines)


def generate() -> str:
    codecs = " | ".join(f'"{c}"' for c in available_codecs())
    parts = [HEADER.format(codecs=f"{codecs} | <registered>"),
             GROUPS_SECTION]
    # Grouping comes from registration provenance (attach_ops records the
    # owning class in OP_OWNERS), not from name heuristics.
    core = [s for s in OP_TABLE.values()
            if OP_OWNERS[s.name] == "Communicator"]
    plugin = [s for s in OP_TABLE.values()
              if OP_OWNERS[s.name] != "Communicator"]
    parts.append(
        f"\n---\n\n# Core collectives ({len(core)} rows)\n"
    )
    parts += [_section(s) for s in core]
    parts.append(
        f"---\n\n# Plugin collectives ({len(plugin)} rows)\n\n"
        "Registered by plugin classes through the *same* table "
        "(`attach_ops`, paper §III-F): grid rows reuse the flat specs "
        "verbatim with a 2-hop routing override; sparse rows add the "
        "`neighbors` parameter kind.\n"
    )
    parts += [_section(s) for s in plugin]
    from repro.checkpoint.manager import CheckpointManager  # noqa: E402
    from repro.core.ulfm import WorldComm  # noqa: E402
    from repro.train.fault_tolerance import FaultTolerantRunner  # noqa: E402
    parts.append(FT_SECTION_HEADER)
    parts += [_ft_section(c)
              for c in (WorldComm, CheckpointManager, FaultTolerantRunner)]
    return "\n".join(parts)


def main(argv) -> int:
    text = generate()
    if "--check" in argv:
        if not os.path.exists(OUT_PATH):
            print("API.md is missing; run: PYTHONPATH=src python "
                  "tools/gen_api_docs.py")
            return 1
        with open(OUT_PATH) as f:
            on_disk = f.read()
        if on_disk != text:
            print("API.md is stale relative to the op-spec table; "
                  "regenerate with: PYTHONPATH=src python "
                  "tools/gen_api_docs.py")
            return 1
        print(f"API.md is up to date ({len(OP_TABLE)} table rows).")
        return 0
    with open(OUT_PATH, "w") as f:
        f.write(text)
    print(f"wrote {OUT_PATH} ({len(OP_TABLE)} table rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
