"""Beyond-paper: MoE dispatch strategies on the communicator — the
paper's §V-A specialized collectives applied to expert parallelism.

Compares (on 8 virtual devices): EP flat alltoallv vs EP grid (2-hop)
vs TP-gathered (no dispatch), over token counts; reports wall time and
staged collective composition.  The production-scale numbers come from
the dry-run HLO (EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import csv_row, time_fn
from repro.models import ModelConfig
from repro.models.moe import (
    init_moe,
    moe_forward_dense,
    moe_forward_ep_local,
    moe_forward_tp_local,
)

CFG = ModelConfig(
    name="bench-moe", family="moe", num_layers=1, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=128, num_experts=16, top_k=2,
    moe_d_ff=512, capacity_factor=1.5, dtype="float32", param_dtype="float32",
)


def run():
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64, CFG.d_model))

    out = {}
    # EP flat
    p_ep = init_moe(jax.random.PRNGKey(1), CFG, ep_size=4)

    def ep_body(px, xx):
        n = xx.shape[0] * xx.shape[1]
        o, _ = moe_forward_ep_local(px, xx.reshape(n, CFG.d_model), CFG, "model")
        return o.reshape(xx.shape)

    in_specs_ep = (
        {"router": P(), "wi": P("model", None, None),
         "wg": P("model", None, None), "wo": P("model", None, None)},
        P("data", "model", None),
    )
    fn = jax.jit(jax.shard_map(ep_body, mesh=mesh, in_specs=in_specs_ep,
                               out_specs=P("data", "model", None),
                               check_vma=False))
    out["ep_flat"] = time_fn(fn, p_ep, x)
    csv_row("moe_dispatch_ep_flat", out["ep_flat"] * 1e6, "2x alltoall")

    # EP flat with the reduce_scatter combine: the return alltoall and the
    # top-k weighted sum fuse into one reduce-scatter (DESIGN.md §2).
    def ep_rs_body(px, xx):
        n = xx.shape[0] * xx.shape[1]
        o, _ = moe_forward_ep_local(px, xx.reshape(n, CFG.d_model), CFG,
                                    "model", combine="reduce_scatter")
        return o.reshape(xx.shape)

    fn = jax.jit(jax.shard_map(ep_rs_body, mesh=mesh, in_specs=in_specs_ep,
                               out_specs=P("data", "model", None),
                               check_vma=False))
    out["ep_flat_rs"] = time_fn(fn, p_ep, x)
    csv_row("moe_dispatch_ep_flat_rs", out["ep_flat_rs"] * 1e6,
            "2x alltoall fwd (tokens+meta) + 1x reduce-scatter combine")

    # EP grid (2-hop over both axes; experts over all 8 ranks)
    p_ep8 = init_moe(jax.random.PRNGKey(1), CFG, ep_size=8)

    def grid_body(px, xx):
        n = xx.shape[0] * xx.shape[1]
        o, _ = moe_forward_ep_local(
            px, xx.reshape(n, CFG.d_model), CFG, ("data", "model"),
            use_grid=True,
        )
        return o.reshape(xx.shape)

    in_specs_g = (
        {"router": P(), "wi": P(("data", "model"), None, None),
         "wg": P(("data", "model"), None, None),
         "wo": P(("data", "model"), None, None)},
        P(("data", "model"), None, None),
    )
    fn = jax.jit(jax.shard_map(grid_body, mesh=mesh, in_specs=in_specs_g,
                               out_specs=P(("data", "model"), None, None),
                               check_vma=False))
    xg = x.reshape(8, 64, CFG.d_model)
    out["ep_grid"] = time_fn(fn, p_ep8, xg)
    csv_row("moe_dispatch_ep_grid", out["ep_grid"] * 1e6,
            "4x sub-alltoall; msgs 2*(sqrt(p)-1)")

    # TP gathered
    p_tp = init_moe(jax.random.PRNGKey(1), CFG, ep_size=1)

    def tp_body(px, xx):
        n = xx.shape[0] * xx.shape[1]
        o, _ = moe_forward_tp_local(px, xx.reshape(n, CFG.d_model), CFG, "model")
        return o.reshape(xx.shape)

    in_specs_tp = (
        {"router": P(), "wi": P(None, None, "model"),
         "wg": P(None, None, "model"), "wo": P(None, "model", None)},
        P("data", None, None),
    )
    fn = jax.jit(jax.shard_map(tp_body, mesh=mesh, in_specs=in_specs_tp,
                               out_specs=P("data", None, None),
                               check_vma=False))
    out["tp"] = time_fn(fn, p_tp, x)
    csv_row("moe_dispatch_tp", out["tp"] * 1e6, "psum only; no dispatch")
    return out


if __name__ == "__main__":
    run()
