"""Serving engine throughput across slot, replica and KV-layout cells.

Drives :class:`repro.serve.ServeEngine` (DESIGN.md §11/§14) with a
synthetic mixed-length request stream on a tiny dense model and records
the engine's own per-phase wall clock (``admit`` / ``prefill`` /
``decode`` / ``reap``) plus decode throughput for each cell:

* ``slots`` ∈ {1, 2, 4, 8} at one replica — continuous-batch width:
  decode tok/s rises with slots because one fixed-shape ``decode_step``
  advances the whole batch;
* ``replicas`` ∈ {1, 2, 4} at 4 slots — the vmap SPMD serve axis:
  every replica's pool decodes inside one island program;
* sharded-pool cells (2 replicas × 2 shards) exercising the grouped
  liveness reduction;
* **paged cells** (DESIGN.md §14) — same simulated KV memory as a dense
  cell (``kv_rows`` column) but more slots: the shared page pool
  oversubscribes capacity, so the paged cell sustains a wider
  continuous batch (higher decode tok/s) at equal memory, deferring
  admission if the pool transiently fills;
* an **auto** cell — ``replica_shards="auto"`` + ``plan="auto"``: shard
  count from the fitted serve sweep, liveness exchange rewritten by the
  planner; ``auto_vs_hand`` compares it against the best hand-pinned
  cell of the same shape.

Warmup (jit compilation of the per-bucket prefill, splice and decode
programs) runs before ``reset_stats``, so the recorded phases time the
steady-state engine only.  Emits benchmarks/artifacts/serve.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from common import csv_row
from repro.models import ModelConfig, init_params
from repro.serve import Request, ServeEngine

CFG = ModelConfig(
    name="bench-serve", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
MAX_LEN = 64
MAX_NEW = 16
PROMPT_LENS = (5, 9, 17)  # buckets 8, 16, 32

# Cell keys: replicas / shards / slots (per replica) / requests, plus the
# optional kv_layout knobs.  Paged cells pick num_pages for *memory
# parity* with a dense comparison cell (see kv_rows in the emitted rows)
# while serving more slots from the shared pool — worst-case request
# need is ceil((17 + 16 - 1) / page_size) pages.
SWEEP = [
    dict(replicas=1, shards=1, slots=1, requests=16),
    dict(replicas=1, shards=1, slots=2, requests=16),
    dict(replicas=1, shards=1, slots=4, requests=16),
    dict(replicas=1, shards=1, slots=8, requests=16),
    dict(replicas=2, shards=1, slots=4, requests=32),
    dict(replicas=4, shards=1, slots=4, requests=64),
    dict(replicas=2, shards=2, slots=4, requests=32),
    # paged at dense-(1,1,4) memory (256 kv rows -> 65 pages x 4 rows =
    # 260), but 8 slots instead of 4:
    dict(replicas=1, shards=1, slots=8, requests=16, layout="paged",
         page_size=4, num_pages=65),
    # paged + planner-routed liveness on the sharded pool
    dict(replicas=2, shards=2, slots=4, requests=32, layout="paged",
         page_size=4, plan="auto"),
    # autotuned: shard count from the fitted serve sweep, planned liveness
    dict(replicas=2, shards="auto", slots=4, requests=32, plan="auto"),
]
SMOKE_SWEEP = [
    dict(replicas=1, shards=1, slots=2, requests=4),
    dict(replicas=2, shards=1, slots=2, requests=4),
    dict(replicas=2, shards=2, slots=2, requests=4),
    dict(replicas=1, shards=1, slots=2, requests=4, layout="paged",
         page_size=4),
    dict(replicas=1, shards=1, slots=2, requests=4, layout="paged",
         page_size=4, plan="auto"),
]


def make_requests(n, rng):
    return [
        Request(prompt=rng.randint(1, CFG.vocab_size,
                                   (PROMPT_LENS[i % len(PROMPT_LENS)],))
                .astype(np.int32),
                max_new_tokens=MAX_NEW)
        for i in range(n)
    ]


def run_cell(params, cell):
    engine = ServeEngine(
        CFG, params, max_len=MAX_LEN, num_slots=cell["slots"],
        num_replicas=cell["replicas"], replica_shards=cell["shards"],
        kv_layout=cell.get("layout", "dense"),
        page_size=cell.get("page_size", 4),
        num_pages=cell.get("num_pages"),
        plan=cell.get("plan"),
    )
    rng = np.random.RandomState(0)
    # warmup: one request per prompt bucket, drained — compiles every
    # program the timed stream will hit
    for r in make_requests(len(PROMPT_LENS), rng):
        engine.submit(r)
    engine.run_to_completion()
    engine.reset_stats()

    reqs = make_requests(cell["requests"], rng)
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run_to_completion()
    total_s = time.perf_counter() - t0
    assert len(done) == cell["requests"] and not engine.truncated
    return engine, total_s


def run(smoke: bool = False, out: str | None = None):
    params = init_params(CFG, jax.random.PRNGKey(0))
    rows = []
    for cell in (SMOKE_SWEEP if smoke else SWEEP):
        # best-of-3 in full mode: single-shot engine runs on a shared CPU
        # box are noisy enough to swamp the auto-vs-hand comparison
        engine, total_s = run_cell(params, cell)
        for _ in range(0 if smoke else 2):
            e2, t2 = run_cell(params, cell)
            if (e2.counters["decode_tokens"] / t2
                    > engine.counters["decode_tokens"] / total_s):
                engine, total_s = e2, t2
        c, ph = engine.counters, engine.phase_seconds
        tok_s = c["decode_tokens"] / total_s if total_s else 0.0
        layout = cell.get("layout", "dense")
        plan = cell.get("plan")
        label = (f"serve_r{cell['replicas']}x{engine.replica_shards}"
                 f"_s{cell['slots']}_{layout}"
                 + ("_planned" if plan else ""))
        csv_row(
            label, total_s * 1e6,
            f"requests={cell['requests']};steps={c['steps']};"
            f"decode_tokens={c['decode_tokens']};tok_per_s={tok_s:.1f}",
        )
        kv_rows = (
            engine.num_ranks * engine.num_pages * engine.page_size
            if engine.paged
            else engine.num_ranks * engine.slots_per_rank * MAX_LEN
        )
        rows.append({
            "replicas": cell["replicas"], "shards": engine.replica_shards,
            "slots": cell["slots"],
            "requests": cell["requests"], "steps": c["steps"],
            "layout": layout, "plan": plan,
            "page_size": engine.page_size, "num_pages": engine.num_pages,
            "kv_rows": kv_rows,
            "pages_in_use": c["pages_in_use_peak"] if engine.paged else None,
            "deferrals": c["admission_deferrals"] if engine.paged else None,
            # resolved shard count when shards="auto" (the serve-pool
            # analogue of group-size autotuning), else None
            "group_size": (engine.replica_shards
                           if cell["shards"] == "auto" else None),
            "auto_vs_hand": None,  # filled below for auto cells
            "decode_tokens": c["decode_tokens"],
            "prefill_tokens": c["prefill_tokens"],
            "prefill_programs": engine.prefill_cache_size(),
            "admit_s": ph["admit"], "prefill_s": ph["prefill"],
            "decode_s": ph["decode"], "reap_s": ph["reap"],
            "total_s": total_s, "decode_tok_per_s": tok_s,
        })
    # auto_vs_hand: autotuned cell vs the best hand-pinned cell of the
    # same (replicas, slots, layout) shape
    for i, (cell, row) in enumerate(zip(SMOKE_SWEEP if smoke else SWEEP,
                                        rows)):
        if cell["shards"] != "auto":
            continue
        hand = [
            r["decode_tok_per_s"] for c2, r in
            zip(SMOKE_SWEEP if smoke else SWEEP, rows)
            if c2["shards"] != "auto"
            and r["replicas"] == row["replicas"]
            and r["slots"] == row["slots"]
            and r["layout"] == row["layout"]
        ]
        if hand and max(hand):
            rows[i]["auto_vs_hand"] = row["decode_tok_per_s"] / max(hand)
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "serve.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells, schema-identical rows")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
