"""Serving engine throughput across slot and replica counts.

Drives :class:`repro.serve.ServeEngine` (DESIGN.md §11) with a synthetic
mixed-length request stream on a tiny dense model and records the
engine's own per-phase wall clock (``admit`` / ``prefill`` / ``decode``
/ ``reap``) plus decode throughput for each cell of a
``slots`` × ``replicas`` sweep:

* ``slots`` ∈ {1, 2, 4, 8} at one replica — continuous-batch width:
  decode tok/s rises with slots because one fixed-shape ``decode_step``
  advances the whole batch;
* ``replicas`` ∈ {1, 2, 4} at 4 slots — the vmap SPMD serve axis:
  every replica's pool decodes inside one island program;
* one sharded-pool cell (2 replicas × 2 shards) exercising the grouped
  liveness reduction.

Warmup (jit compilation of the per-bucket prefill, splice and decode
programs) runs before ``reset_stats``, so the recorded phases time the
steady-state engine only.  Emits benchmarks/artifacts/serve.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from common import csv_row
from repro.models import ModelConfig, init_params
from repro.serve import Request, ServeEngine

CFG = ModelConfig(
    name="bench-serve", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
MAX_LEN = 64
MAX_NEW = 16
PROMPT_LENS = (5, 9, 17)  # buckets 8, 16, 32

# (replicas, shards, slots-per-replica, total requests)
SWEEP = [
    (1, 1, 1, 16), (1, 1, 2, 16), (1, 1, 4, 16), (1, 1, 8, 16),
    (2, 1, 4, 32), (4, 1, 4, 64),
    (2, 2, 4, 32),
]
SMOKE_SWEEP = [(1, 1, 2, 4), (2, 1, 2, 4)]


def make_requests(n, rng):
    return [
        Request(prompt=rng.randint(1, CFG.vocab_size,
                                   (PROMPT_LENS[i % len(PROMPT_LENS)],))
                .astype(np.int32),
                max_new_tokens=MAX_NEW)
        for i in range(n)
    ]


def run_cell(params, replicas, shards, slots, n_requests):
    engine = ServeEngine(CFG, params, max_len=MAX_LEN, num_slots=slots,
                         num_replicas=replicas, replica_shards=shards)
    rng = np.random.RandomState(0)
    # warmup: one request per prompt bucket, drained — compiles every
    # program the timed stream will hit
    for r in make_requests(len(PROMPT_LENS), rng):
        engine.submit(r)
    engine.run_to_completion()
    engine.reset_stats()

    reqs = make_requests(n_requests, rng)
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run_to_completion()
    total_s = time.perf_counter() - t0
    assert len(done) == n_requests and not engine.truncated
    return engine, total_s


def run(smoke: bool = False, out: str | None = None):
    params = init_params(CFG, jax.random.PRNGKey(0))
    rows = []
    for replicas, shards, slots, n_requests in (SMOKE_SWEEP if smoke
                                                else SWEEP):
        engine, total_s = run_cell(params, replicas, shards, slots,
                                   n_requests)
        c, ph = engine.counters, engine.phase_seconds
        tok_s = c["decode_tokens"] / total_s if total_s else 0.0
        csv_row(
            f"serve_r{replicas}x{shards}_s{slots}", total_s * 1e6,
            f"requests={n_requests};steps={c['steps']};"
            f"decode_tokens={c['decode_tokens']};tok_per_s={tok_s:.1f}",
        )
        rows.append({
            "replicas": replicas, "shards": shards, "slots": slots,
            "requests": n_requests, "steps": c["steps"],
            "decode_tokens": c["decode_tokens"],
            "prefill_tokens": c["prefill_tokens"],
            "prefill_programs": engine.prefill_cache_size(),
            "admit_s": ph["admit"], "prefill_s": ph["prefill"],
            "decode_s": ph["decode"], "reap_s": ph["reap"],
            "total_s": total_s, "decode_tok_per_s": tok_s,
        })
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "serve.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two tiny cells, schema-identical rows")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
