"""Paper §V-C / Fig. 13: reproducible reduce.

Validates bitwise p-invariance and compares cost against (a) the naive
gather + local-reduce + broadcast the paper beats, and (b) the raw psum
lower bound (which is *not* p-invariant)."""
from __future__ import annotations

import operator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import csv_row, time_fn
from repro.core import Communicator, ReproducibleReduce, op, send_buf

M_LEAVES = 32
DIM = 4096


def run():
    leaves = (np.random.RandomState(0).randn(M_LEAVES, DIM) * 1e3).astype(np.float32)

    results = {}
    for p in (1, 2, 4, 8):
        mesh = jax.make_mesh((p,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        def repro(x):
            comm = Communicator("x").extend(ReproducibleReduce)
            return comm.reproducible_allreduce(send_buf(x))

        fn = jax.jit(jax.shard_map(repro, mesh=mesh, in_specs=P("x"),
                                   out_specs=P(None), check_vma=False))
        results[p] = np.asarray(fn(leaves))
    invariant = all((results[p] == results[1]).all() for p in (2, 4, 8))
    csv_row("reproducible_reduce_p_invariant", 0.0, f"bitwise={invariant}")
    assert invariant

    mesh8 = jax.make_mesh((8,), ("x",),
                          axis_types=(jax.sharding.AxisType.Auto,))

    def repro8(x):
        comm = Communicator("x").extend(ReproducibleReduce)
        return comm.reproducible_allreduce(send_buf(x))

    def gather_reduce_bcast(x):
        g = jax.lax.all_gather(x, "x", tiled=True)  # (M, DIM) on all
        return jnp.sum(g, axis=0)

    def raw_psum(x):
        return jax.lax.psum(jnp.sum(x, 0), "x")

    rows = {}
    for name, fn in (("tree", repro8), ("gather_reduce", gather_reduce_bcast),
                     ("raw_psum", raw_psum)):
        jfn = jax.jit(jax.shard_map(fn, mesh=mesh8, in_specs=P("x"),
                                    out_specs=P(None), check_vma=False))
        t = time_fn(jfn, leaves)
        vol = {"tree": "log2(p)*payload", "gather_reduce": "p*payload",
               "raw_psum": "2*payload"}[name]
        csv_row(f"reproducible_{name}", t * 1e6, f"wire_volume={vol}")
        rows[name] = t

    # correctness cross-check: tree == psum up to fp reassociation
    a = np.asarray(jax.jit(jax.shard_map(repro8, mesh=mesh8, in_specs=P("x"),
                                         out_specs=P(None), check_vma=False))(leaves))
    b = leaves.sum(0)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1.0)
    return {"invariant": invariant, **rows}


if __name__ == "__main__":
    run()
