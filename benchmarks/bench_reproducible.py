"""Paper §V-C / Fig. 13: deterministic (p-invariant) tree reduction.

Exercises the engine-level ``deterministic("tree", leaves=m)`` parameter
(DESIGN.md §12) under the vmap-as-SPMD interpreter:

* **p-invariance** — the same global leaf stack reduced at
  p ∈ {1, 2, 4, 8} must be bitwise identical (asserted, and recorded in
  the artifact as ``bitwise_p_invariant``);
* **cost** — at p = 8, the canonical tree (2·log2(p) ppermute hops on a
  payload-sized vector) vs the naive gather + local-reduce + broadcast
  the paper beats (p·payload wire) vs the raw psum lower bound (which is
  *not* p-invariant);
* **codec composition** — ``deterministic`` + ``compression("int8-ef")``
  (quantized-leaf semantics: encode once, tree-accumulate the exact
  int32 accumulator).

On CPU the wall numbers characterize the *staged program*; the
transferable number is the wire-volume column.  Emits the standard
report JSON (benchmarks/artifacts/reproducible.json) plus csv_row lines;
``--smoke``/``--out`` follow the bench-smoke conventions (tiny payload,
1 rep, schema-identical rows).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from common import PAYLOAD_SIZES, SMOKE_PAYLOAD_SIZES, csv_row, make_timer
from repro.core import Communicator, compression, deterministic, op, send_buf

M_LEAVES = 8          # global leaf count shared by every p
P_RANKS = 8           # the timing comparison's fixed size
PS = (1, 2, 4, 8)


def _spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def _det_allreduce_fn(m, codec=None):
    def f(v):
        comm = Communicator("x")
        args = [send_buf(v), op("sum"), deterministic("tree", leaves=m)]
        if codec is not None:
            args.append(compression(codec))
        return comm.allreduce(*args)

    return _spmd(f)


def _gather_reduce_fn():
    # the naive baseline the paper beats: all-gather every rank's leaf,
    # reduce locally (the "broadcast" is implicit — all ranks gather)
    return _spmd(lambda v: jnp.sum(jax.lax.all_gather(v, "x"), axis=0))


def _raw_psum_fn():
    return _spmd(lambda v: jax.lax.psum(jnp.sum(v, 0), "x"))


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    rows = []
    for n in (SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES):
        payload_bytes = n * 4
        data = (np.random.RandomState(0).randn(M_LEAVES, n) * 1e3).astype(
            np.float32
        )

        # -- bitwise p-invariance of the fixed global tree ----------------
        vals = {}
        for p in PS:
            m = M_LEAVES // p
            vals[p] = np.asarray(
                _det_allreduce_fn(m)(jnp.asarray(data.reshape(p, m, n)))
            )[0]
        invariant = all(
            np.array_equal(vals[p], vals[1]) for p in PS[1:]
        )
        assert invariant, "deterministic tree is not p-invariant"
        csv_row(
            f"reproducible_p_invariant_n{n}", 0.0,
            f"bitwise={invariant};M={M_LEAVES};payload_bytes={payload_bytes}",
        )
        rows.append({
            "mode": "p_invariance", "codec": None, "p": None,
            "leaves": M_LEAVES, "payload_bytes": payload_bytes,
            "bitwise_p_invariant": invariant, "wire_volume": None,
            "us": None,
        })

        # -- cost at p = 8: tree vs gather+reduce vs raw psum -------------
        m8 = M_LEAVES // P_RANKS
        stacked = jnp.asarray(data.reshape(P_RANKS, m8, n))
        flat = jnp.asarray(data.reshape(P_RANKS, n))
        timed = (
            ("tree", _det_allreduce_fn(m8), stacked, "2*log2(p)*payload"),
            ("gather_reduce", _gather_reduce_fn(), flat, "p*payload"),
            ("raw_psum", _raw_psum_fn(), stacked, "2*payload"),
        )
        for name, fn, x, vol in timed:
            us = time_fn(fn, x) * 1e6
            csv_row(
                f"reproducible_{name}", us,
                f"p={P_RANKS};payload_bytes={payload_bytes};"
                f"wire_volume={vol}",
            )
            rows.append({
                "mode": name, "codec": None, "p": P_RANKS,
                "leaves": M_LEAVES, "payload_bytes": payload_bytes,
                "bitwise_p_invariant": None, "wire_volume": vol,
                "us": us,
            })

        # -- codec composition: deterministic + int8-ef -------------------
        us = time_fn(_det_allreduce_fn(m8, codec="int8-ef"), stacked) * 1e6
        csv_row(
            "reproducible_tree_int8ef", us,
            f"p={P_RANKS};payload_bytes={payload_bytes}",
        )
        rows.append({
            "mode": "tree", "codec": "int8-ef", "p": P_RANKS,
            "leaves": M_LEAVES, "payload_bytes": payload_bytes,
            "bitwise_p_invariant": None, "wire_volume": "2*log2(p)*payload/4",
            "us": us,
        })

        # correctness cross-check: tree == plain sum up to reassociation
        np.testing.assert_allclose(
            vals[1], data.sum(0), rtol=1e-4, atol=1.0
        )

    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "reproducible.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
