"""Paper Fig. 11 (RAxML-NG): serialized-object broadcast.

The paper replaced a hand-written serialize + size-bcast + payload-bcast
with one ``bcast(send_recv_buf(as_serialized(obj)))``.  We measure our
staged equivalent against the manual two-phase pattern and verify the
one-call version stages no extra communication."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import csv_row, time_fn
from repro.core import (
    Communicator,
    as_serialized,
    deserialize_like,
    root,
    send_recv_buf,
)

P_RANKS = 8


def _tree():
    rng = np.random.RandomState(0)
    return {
        "model_params": rng.randn(64, 64).astype(np.float32),
        # float32: jax defaults to x32, float64 would silently truncate
        "branch_lengths": rng.rand(128).astype(np.float32),
        "flags": rng.rand(16) > 0.5,
    }


def run():
    mesh = jax.make_mesh((P_RANKS,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tree = _tree()

    def kamping_bcast(leaves):
        comm = Communicator("x")
        s = as_serialized(leaves)
        return comm.bcast(send_recv_buf(s), root(0))

    def manual_bcast(leaves):
        # hand-written: bcast each leaf separately (the "before" in Fig 11)
        comm = Communicator("x")
        return jax.tree.map(
            lambda l: comm.bcast(send_recv_buf(l), root(0)), leaves
        )

    for name, fn in (("serialized", kamping_bcast), ("per_leaf", manual_bcast)):
        jfn = jax.jit(jax.shard_map(
            fn, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree),
            check_vma=False,
        ))
        t = time_fn(jfn, tree)
        csv_row(f"bcast_{name}", t * 1e6, "fig11_raxml")

    # staged-collective count: serialized = 1 bcast; per-leaf = n bcasts
    import re

    def count(fn):
        txt = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False,
        )).lower(jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
        )).as_text()
        return len(re.findall(r"all[-_]reduce|collective[-_]broadcast", txt))

    c1, cn = count(kamping_bcast), count(manual_bcast)
    csv_row("bcast_collectives_serialized", c1, "one_wire_message")
    csv_row("bcast_collectives_per_leaf", cn, f"n_leaves={len(jax.tree.leaves(tree))}")
    # roundtrip correctness
    out = jax.jit(jax.shard_map(
        kamping_bcast, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), tree),),
        out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False,
    ))(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), tree[k])
    return {"collectives_serialized": c1, "collectives_per_leaf": cn}


if __name__ == "__main__":
    run()
