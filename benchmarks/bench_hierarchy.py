"""Flat vs. hierarchical ("hier") two-level gradient reduction.

For every payload size in ``benchmarks/common.PAYLOAD_SIZES`` and group
size g ∈ {2, 4}, times the table-generated ``allreduce`` under the
vmap-as-SPMD interpreter at p=8:

* **flat**  — the single-level xla transport (`lax.psum`);
* **hier**  — `HierTransport(group_size=g)`: intra-group reduce-scatter
  → cross-group allreduce of the 1/g-sized chunks → intra-group
  allgather (DESIGN.md §9), per-level backends xla/xla (the pallas
  intra variant is timed as a third cell at the largest payload).

On CPU this times the *staged op mix* (the transferable number: two
grouped HLO legs + a 1/g-sized cross-group reduction vs one full-size
psum); on a TPU mesh the same code measures the real two-fabric win —
the cross-group fabric only carries 1/g of the payload.  Also reports
the per-rank **cross-group bytes** per schedule, which is exact at
trace time and hardware-independent.

Emits the standard report JSON (benchmarks/artifacts/hierarchy.json)
plus csv_row lines for the console.
"""
from __future__ import annotations

import argparse
import json
import operator
import os

import jax
import numpy as np

from common import PAYLOAD_SIZES, SMOKE_PAYLOAD_SIZES, csv_row, make_timer
from repro.core import Communicator, HierTransport, op, send_buf

P_RANKS = 8
GROUP_SIZES = (2, 4)
SMOKE_GROUP_SIZES = (2,)


def _spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def _allreduce_fn(transport):
    return _spmd(
        lambda v: Communicator("x", transport=transport).allreduce(
            send_buf(v), op(operator.add)
        )
    )


def _cross_group_bytes(n: int, g: int | None) -> int:
    """Per-rank bytes crossing a group boundary per allreduce (float32).

    Flat ring: the whole payload crosses whatever boundary cuts the
    ring, ~2·(p-1)/p·n elements through every rank.  Hier: only the
    cross-group allreduce leg leaves the group — ~2·(nb-1)/nb of the
    1/g-sized chunk.
    """
    if g is None:
        return 4 * 2 * (P_RANKS - 1) * n // P_RANKS
    nb = P_RANKS // g
    chunk = -(-n // g)
    return 4 * 2 * (nb - 1) * chunk // nb


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    payload_sizes = SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES
    group_sizes = SMOKE_GROUP_SIZES if smoke else GROUP_SIZES
    rows = []
    for n in payload_sizes:
        payload_bytes = n * 4
        x = np.random.RandomState(0).randn(P_RANKS, n).astype(np.float32)

        cells = [("flat", None, "xla", "xla")]
        for g in group_sizes:
            cells.append((f"hier_g{g}", g, "xla", "xla"))
        if n == max(payload_sizes):
            cells.append(
                (f"hier_g{group_sizes[-1]}_pallas_intra", group_sizes[-1],
                 "pallas", "xla")
            )

        for name, g, intra, inter in cells:
            t = (
                "xla" if g is None
                else HierTransport(group_size=g, intra=intra, inter=inter)
            )
            us = time_fn(_allreduce_fn(t), x) * 1e6
            xbytes = _cross_group_bytes(n, g)
            csv_row(
                f"hierarchy_allreduce_{name}", us,
                f"p={P_RANKS};payload_bytes={payload_bytes};"
                f"cross_group_bytes={xbytes}",
            )
            rows.append(
                {
                    "op": "allreduce",
                    "schedule": name,
                    "group_size": g,
                    "intra": intra,
                    "inter": inter,
                    "p": P_RANKS,
                    "payload_bytes": payload_bytes,
                    "cross_group_bytes_per_rank": xbytes,
                    "us": us,
                }
            )
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "hierarchy.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
