"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model: TPU v5e.
  compute term    = HLO_FLOPs_global / (chips * 197e12 FLOP/s)
  memory term     = HLO_bytes_per_chip / 819e9 B/s
  collective term = collective_bytes_per_chip / (links_per_chip? -> spec
                    formula: collective_bytes / (chips * 50e9 B/s))

``cost_analysis`` on the compiled (post-SPMD) module reports *per-device*
FLOPs and bytes.  Collective bytes are not in cost_analysis: we parse the
compiled HLO text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute /
collective-broadcast op.
"""
from __future__ import annotations

import re
from typing import Dict

__all__ = ["HW", "parse_collective_bytes", "roofline_terms", "MODEL_FLOPS"]

HW = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,       # B/s per chip
    "ici_bw": 50e9,        # B/s per link (spec constant)
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind from (post-SPMD) HLO.

    ``*-start`` ops are counted; their ``-done`` twins are skipped to avoid
    double counting (async collectives appear as start/done pairs).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        ty, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(ty)
    return out


def MODEL_FLOPS(cfg, tokens: int) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE)."""
    return 6.0 * cfg.active_param_count() * tokens


def roofline_terms(cost: Dict, collective_bytes: int, chips: int,
                   hw=HW) -> Dict[str, float]:
    flops_per_dev = float(cost.get("flops", 0.0))
    bytes_per_dev = float(cost.get("bytes accessed", 0.0))
    t_compute = flops_per_dev / hw["peak_flops"]
    t_memory = bytes_per_dev / hw["hbm_bw"]
    t_coll = collective_bytes / hw["ici_bw"]
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dom,
        "flops_per_device": flops_per_dev,
        "bytes_per_device": bytes_per_dev,
        "collective_bytes_per_device": collective_bytes,
    }
