"""Hand-tuned overlap knobs vs the cost-model planner (DESIGN.md §13).

For each payload sweep (a synthetic gradient pytree under the vmap SPMD
interpreter at p=8, the bench_transports.py idiom) this times

* ``hand`` — a grid of explicit knob settings (transport × per-bucket
  collective × ``bucket_bytes``), the way a user would tune
  ``overlap_reduce_tree`` by hand; and
* ``auto`` — ``plan="auto"``: the :class:`~repro.core.CostModel` fitted
  from the checked-in ``benchmarks/artifacts/*.json`` picks transport /
  mode / bucket bytes / in-flight bound, and the rewrite rules
  (fuse / merge / hoist / reorder) reshape the schedule — all
  bitwise-neutral (tests/test_planner_equivalence.py).

Each row also reports ``wire_bytes_per_rank``, computed from the staged
schedule: every collective node's payload counted once (quantized
buckets at the codec's wire width plus a 4-byte scale per bucket; an
allreduce's internal RS+AG double-pass is a transport property, not a
schedule one).  The ``auto`` rows carry ``auto_vs_hand`` — auto time
over the sweep's best hand time; <= 1.05 means the planner matched or
beat hand tuning on that sweep (the acceptance bar: at least one sweep
must).

A second leg sweeps the **hierarchical group size** (DESIGN.md §9/§14):
flat xla allreduce vs ``HierTransport(group_size=g)`` for the measured
divisors of p, vs the fitted :meth:`CostModel.autotune_group_size` pick
— the ``auto`` row's ``auto_vs_hand`` holds it to the same <= 1.05 bar.

Emits benchmarks/artifacts/planner.json (schema-gated by
check_artifacts.py on the CI bench-smoke leg).
"""
from __future__ import annotations

import argparse
import json
import operator
import os

import jax
import numpy as np

from common import csv_row, make_timer
from repro.core import (
    ALL_RULES,
    Communicator,
    get_codec,
    op as op_param,
    overlap_reduce_tree,
    plan_buckets,
    send_buf,
)
from repro.core.hier import HierTransport
from repro.core.overlap import _build_schedule
from repro.core.planner import CostModel, apply_rules, resolve_plan

P_RANKS = 8
TRANSPORTS = ("xla", "pallas")
MODES = ("allreduce", "reduce_scatter")
BUCKET_BYTES = (1 << 14, 1 << 18, 1 << 22)
MAX_INFLIGHT = 2
CODECS = (None, "int8-ef")

# Payload sweeps: bias/norm-heavy (many tiny leaves, latency-bound),
# transformer mix (the bench_overlap.py tree, bandwidth + schedule).
PAYLOADS = {
    "small-leaves": [64] * 48 + [1024] * 8,
    "transformer-mix": [64] * 24 + [4096] * 8 + [65536] * 4,
}
SMOKE_PAYLOADS = {"smoke": [64] * 4 + [1024] * 2}
SMOKE_BUCKET_BYTES = (1 << 12,)

# Group-size leg: payload bytes per rank for the hier allreduce sweep
# (matches the hierarchy.json measurement points).
HIER_PAYLOAD_BYTES = (4096, 65536)
SMOKE_HIER_PAYLOAD_BYTES = (4096,)
HIER_GROUPS = (2, 4)  # divisors of P_RANKS with 1 < g < p


def make_tree(p, leaf_sizes):
    rng = np.random.RandomState(0)
    return {
        f"leaf{i:02d}": rng.randn(p, n).astype(np.float32)
        for i, n in enumerate(leaf_sizes)
    }


def reduction(transport, codec, **kw):
    def f(tree):
        comm = Communicator("x", transport=transport)
        # no err_state: the engine returns just the reduced tree
        return overlap_reduce_tree(
            comm, tree, scale=1.0 / comm.size(),
            compression=codec, **kw
        )

    return f


def spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def wire_bytes_per_rank(tree, *, bucket_bytes, mode, codec_name, rules, p):
    """Interconnect bytes per rank per step, from the staged schedule."""
    leaves = [v[0] for v in jax.tree.leaves(tree)]
    codec = get_codec(codec_name) if codec_name else None
    prog = _build_schedule(
        plan_buckets(leaves, bucket_bytes),
        mode=mode, codec=codec, deterministic=None, p=p,
    )
    prog = apply_rules(prog, rules, {
        "bucket_bytes": bucket_bytes,
        "codec_quantized": codec is not None,
    })
    total = 0
    for node in prog.ops:
        if node.op == "scale_exchange":
            total += 4 * len(node.meta["buckets"])
        elif node.param("compression") is not None:
            # quantized wire width (1 byte for int8-ef / fp8-e4m3) + the
            # per-bucket scale, unless a hoisted exchange already sent it
            total += node.meta["total"]
            if not any(
                prog.ops[d].op == "scale_exchange" for d in node.deps
            ):
                total += 4 * len(node.meta["buckets"])
        else:
            total += node.nbytes
    return total


def _hier_allreduce(group_size):
    """Flat xla allreduce (group_size None) or the two-level hier one."""
    transport = (
        "xla" if group_size is None else HierTransport(group_size=group_size)
    )

    def f(x):
        comm = Communicator("x", transport=transport)
        return comm.allreduce(send_buf(x), op_param(operator.add))

    return f


def run_group_size_leg(time_fn, smoke):
    """Flat vs hand-pinned hier group sizes vs the fitted autotune pick
    (DESIGN.md §14): same row schema as the bucket-grid legs, with
    ``group_size`` carrying the hier split (None = flat)."""
    rows = []
    model = CostModel.fit()
    sizes = SMOKE_HIER_PAYLOAD_BYTES if smoke else HIER_PAYLOAD_BYTES
    for nbytes in sizes:
        x = np.random.RandomState(0).randn(
            P_RANKS, nbytes // 4
        ).astype(np.float32)
        best_us = None
        for g in (None,) + HIER_GROUPS:
            us = time_fn(spmd(_hier_allreduce(g)), x) * 1e6
            csv_row(f"planner_group_hand_{nbytes}b", us,
                    f"group_size={g};transport={'xla' if g is None else 'hier'}")
            rows.append({
                "payload": f"hier-{nbytes}b", "p": P_RANKS,
                "grad_bytes": nbytes, "codec": None, "strategy": "hand",
                "transport": "xla" if g is None else "hier",
                "mode": "allreduce", "bucket_bytes": None,
                "max_inflight": None, "n_rules": 0, "us": us,
                "wire_bytes_per_rank": None, "auto_vs_hand": None,
                "group_size": g,
            })
            if best_us is None or us < best_us:
                best_us = us
        g_auto = model.autotune_group_size(float(nbytes), P_RANKS)
        us = time_fn(spmd(_hier_allreduce(g_auto)), x) * 1e6
        ratio = us / best_us
        csv_row(f"planner_group_auto_{nbytes}b", us,
                f"group_size={g_auto};auto_vs_hand={ratio:.3f}")
        rows.append({
            "payload": f"hier-{nbytes}b", "p": P_RANKS,
            "grad_bytes": nbytes, "codec": None, "strategy": "auto",
            "transport": "xla" if g_auto is None else "hier",
            "mode": "allreduce", "bucket_bytes": None,
            "max_inflight": None, "n_rules": 0, "us": us,
            "wire_bytes_per_rank": None, "auto_vs_hand": ratio,
            "group_size": g_auto,
        })
    return rows


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    payloads = SMOKE_PAYLOADS if smoke else PAYLOADS
    bucket_grid = SMOKE_BUCKET_BYTES if smoke else BUCKET_BYTES
    rows = []
    for pname, leaf_sizes in payloads.items():
        tree = make_tree(P_RANKS, leaf_sizes)
        grad_bytes = sum(v.nbytes // P_RANKS for v in tree.values())
        for codec_name in CODECS:
            codec = get_codec(codec_name) if codec_name else None
            best_us, best_cell = None, None
            for t in TRANSPORTS:
                for mode in MODES:
                    for bb in bucket_grid:
                        fn = reduction(
                            t, codec, bucket_bytes=bb, mode=mode,
                            max_inflight=MAX_INFLIGHT,
                        )
                        us = time_fn(spmd(fn), tree) * 1e6
                        wire = wire_bytes_per_rank(
                            tree, bucket_bytes=bb, mode=mode,
                            codec_name=codec_name, rules=(), p=P_RANKS,
                        )
                        csv_row(
                            f"planner_hand_{pname}_{codec_name or 'raw'}",
                            us,
                            f"t={t};mode={mode};bucket={bb};wire={wire}",
                        )
                        rows.append({
                            "payload": pname, "p": P_RANKS,
                            "grad_bytes": grad_bytes,
                            "codec": codec_name, "strategy": "hand",
                            "transport": t, "mode": mode,
                            "bucket_bytes": bb,
                            "max_inflight": MAX_INFLIGHT,
                            "n_rules": 0, "us": us,
                            "wire_bytes_per_rank": wire,
                            "auto_vs_hand": None,
                            "group_size": None,
                        })
                        if best_us is None or us < best_us:
                            best_us, best_cell = us, (t, mode, bb)

            plan = resolve_plan(
                "auto", total_bytes=grad_bytes, p=P_RANKS,
                codec=codec_name,
            )
            fn = reduction(None, codec, plan=plan)
            us = time_fn(spmd(fn), tree) * 1e6
            wire = wire_bytes_per_rank(
                tree,
                bucket_bytes=plan.bucket_bytes or (4 << 20),
                mode=plan.mode or "allreduce",
                codec_name=codec_name, rules=plan.rules, p=P_RANKS,
            )
            ratio = us / best_us
            csv_row(
                f"planner_auto_{pname}_{codec_name or 'raw'}", us,
                f"plan={plan.describe()};auto_vs_hand={ratio:.3f};"
                f"hand_best={best_cell};wire={wire}",
            )
            rows.append({
                "payload": pname, "p": P_RANKS, "grad_bytes": grad_bytes,
                "codec": codec_name, "strategy": "auto",
                "transport": plan.transport, "mode": plan.mode,
                "bucket_bytes": plan.bucket_bytes,
                "max_inflight": plan.max_inflight,
                "n_rules": len(plan.rules), "us": us,
                "wire_bytes_per_rank": wire,
                "auto_vs_hand": ratio,
                "group_size": plan.group_size,
            })
    rows.extend(run_group_size_leg(time_fn, smoke))
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "planner.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    autos = [r for r in rows if r["strategy"] == "auto"]
    hit = [r for r in autos if r["auto_vs_hand"] <= 1.05]
    print(
        f"auto within 5% of (or beating) best hand-tuned on "
        f"{len(hit)}/{len(autos)} sweeps"
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tree, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
