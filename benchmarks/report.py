"""Render EXPERIMENTS.md tables from the dry-run JSON artifacts."""
from __future__ import annotations

import json
import os
import sys


def _fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def _fmt_s(t):
    if t == 0:
        return "0"
    if t < 1e-3:
        return f"{t*1e6:.0f}us"
    if t < 1:
        return f"{t*1e3:.1f}ms"
    return f"{t:.2f}s"


def load(mesh_name):
    path = os.path.join(os.path.dirname(__file__), "artifacts",
                        f"dryrun_{mesh_name}.json")
    return json.load(open(path))


def dryrun_table(recs):
    rows = ["| arch | shape | status | bytes/dev (args+temp) | compile |",
            "|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "ok":
            m = r["memory"]
            mem = _fmt_bytes(m["argument_bytes"] + m["temp_bytes"])
            rows.append(
                f"| {r['arch']} | {r['shape']} | ok | {mem} | "
                f"{r['compile_s']}s |"
            )
        elif r["status"] == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | skip | — | — |"
            )
        else:
            rows.append(
                f"| {r['arch']} | {r['shape']} | **FAIL** | {r.get('error','')[:60]} | — |"
            )
    return "\n".join(rows)


def roofline_table(recs):
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant "
        "| useful (6ND/HLO) | bound-step |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['t_compute'])} | "
            f"{_fmt_s(t['t_memory'])} | {_fmt_s(t['t_collective'])} | "
            f"{t['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{_fmt_s(bound)} |"
        )
    return "\n".join(rows)


def collective_table(recs):
    rows = ["| arch | shape | all-reduce | all-gather | reduce-scatter | "
            "all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            continue
        c = r["collective_bytes"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{_fmt_bytes(c.get('all-reduce', 0))} | "
            f"{_fmt_bytes(c.get('all-gather', 0))} | "
            f"{_fmt_bytes(c.get('reduce-scatter', 0))} | "
            f"{_fmt_bytes(c.get('all-to-all', 0))} | "
            f"{_fmt_bytes(c.get('collective-permute', 0))} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    for mesh in sys.argv[1:] or ["pod16x16", "multipod2x16x16"]:
        recs = load(mesh)
        print(f"\n## {mesh}\n")
        print(dryrun_table(recs))
        print()
        print(roofline_table(recs))
