"""Paper Fig. 8: sample-sort running time, KaMPIng API vs hand-rolled.

The paper's claim: the convenience layer introduces no overhead over
hand-rolled MPI.  Here: identical staged collectives (HLO parity) and
statistically indistinguishable wall time on 8 virtual devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import collective_ops, csv_row, time_fn
from repro.core import (
    Communicator,
    bucketize_by_destination,
    recv_counts_out,
    send_buf,
    send_counts,
)

P_RANKS = 8
N = 1 << 12
OVERSAMPLE = 16


def _mesh():
    return jax.make_mesh((P_RANKS,), ("ranks",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _sort_kamping(data, key):
    key = key[0]  # local (1, 2) key shard -> scalar key
    comm = Communicator("ranks")
    p = comm.size()
    samples = jax.random.choice(key, data, (OVERSAMPLE,), replace=False)
    gs = jnp.sort(comm.allgather(send_buf(samples)).reshape(-1))
    splitters = gs[OVERSAMPLE::OVERSAMPLE][: p - 1]
    dest = jnp.searchsorted(splitters, data).astype(jnp.int32)
    cap = int(N * 2.5 / p) * 2
    buckets, counts = bucketize_by_destination(
        data, dest, p, cap, pad_value=jnp.iinfo(jnp.int32).max
    )
    r = comm.alltoallv(send_buf(buckets), send_counts(counts), recv_counts_out())
    return jnp.sort(r.recv_buf.reshape(-1)), jnp.sum(r.recv_counts)[None]


def _sort_handrolled(data, key):
    key = key[0]
    p = jax.lax.axis_size("ranks")
    samples = jax.random.choice(key, data, (OVERSAMPLE,), replace=False)
    gs = jnp.sort(jax.lax.all_gather(samples, "ranks", tiled=True))
    splitters = gs[OVERSAMPLE::OVERSAMPLE][: p - 1]
    dest = jnp.searchsorted(splitters, data).astype(jnp.int32)
    cap = int(N * 2.5 / p) * 2
    buckets, counts = bucketize_by_destination(
        data, dest, p, cap, pad_value=jnp.iinfo(jnp.int32).max
    )
    buf = jax.lax.all_to_all(buckets, "ranks", 0, 0, tiled=True)
    rcounts = jax.lax.all_to_all(
        counts.reshape(p, 1), "ranks", 0, 0, tiled=True
    ).reshape(p)
    return jnp.sort(buf.reshape(-1)), jnp.sum(rcounts)[None]


def run():
    mesh = _mesh()
    rng = np.random.RandomState(0)
    data = rng.randint(0, 1 << 30, (P_RANKS * N,)).astype(np.int32)
    keys = jax.random.split(jax.random.PRNGKey(0), P_RANKS)

    results = {}
    for name, fn in (("kamping", _sort_kamping), ("handrolled", _sort_handrolled)):
        jfn = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=(P("ranks"), P("ranks")),
            out_specs=(P("ranks"), P("ranks")), check_vma=False,
        ))
        t = time_fn(jfn, data, keys)
        out, _ = jfn(data, keys)
        results[name] = t
        csv_row(f"sample_sort_{name}", t * 1e6,
                f"n={data.size};ranks={P_RANKS}")

    overhead = results["kamping"] / results["handrolled"] - 1
    csv_row("sample_sort_overhead_pct", overhead * 100, "fig8_zero_overhead")
    return {"overhead_frac": overhead, **results}


if __name__ == "__main__":
    run()
