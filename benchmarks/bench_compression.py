"""Compressed vs uncompressed gradient all-reduce (DESIGN.md §10).

For every payload size in ``benchmarks/common.PAYLOAD_SIZES`` and codec
in the engine registry's built-ins, times the table-generated
``allreduce`` under the vmap-as-SPMD interpreter at p=8:

* **none**      — the uncompressed baseline (the pre-codec path);
* **int8-ef**   — int8 + error feedback, exact int32 accumulator;
* **fp8-e4m3**  — emulated fp8 grid, fp32 accumulator;
* **topk**      — sparse (index, value) pairs over the sparse plugin's
  offset-permute exchange.

On CPU the wall numbers characterize the *staged program* (quantize +
accumulate + dequantize vs one psum); the transferable, hardware-
independent number is each codec's **wire bytes per rank** — exact at
trace time (``repro.core.compression.wire_report``) and also surfaced
by the dry-run's ``grad_wire`` record (~4x for int8 on the gradient
all-reduce).

Emits the standard report JSON (benchmarks/artifacts/compression.json)
plus csv_row lines for the console; ``--smoke``/``--out`` follow the
bench-smoke conventions (tiny payload, 1 rep, schema-identical rows).
"""
from __future__ import annotations

import argparse
import json
import operator
import os

import jax
import numpy as np

from common import PAYLOAD_SIZES, SMOKE_PAYLOAD_SIZES, csv_row, make_timer
from repro.core import Communicator, compression, op, send_buf, wire_report

P_RANKS = 8
CODECS = (None, "int8-ef", "fp8-e4m3", "topk")


def _spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def _allreduce_fn(codec):
    def f(v):
        comm = Communicator("x")
        if codec is None:
            return comm.allreduce(send_buf(v), op(operator.add))
        return comm.allreduce(
            send_buf(v), op(operator.add), compression(codec)
        )

    return _spmd(f)


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    rows = []
    for n in (SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES):
        payload_bytes = n * 4
        x = np.random.RandomState(0).randn(P_RANKS, n).astype(np.float32)
        for codec in CODECS:
            us = time_fn(_allreduce_fn(codec), x) * 1e6
            rep = wire_report(
                [np.zeros((n,), np.float32)], codec
            )
            csv_row(
                f"compression_allreduce_{codec or 'none'}", us,
                f"p={P_RANKS};payload_bytes={payload_bytes};"
                f"wire_bytes={rep['wire_bytes']};"
                f"ratio={rep['ratio']:.2f}",
            )
            rows.append(
                {
                    "op": "allreduce",
                    "codec": codec,
                    "p": P_RANKS,
                    "payload_bytes": payload_bytes,
                    "wire_bytes_per_rank": rep["wire_bytes"],
                    "wire_ratio": rep["ratio"],
                    "us": us,
                }
            )
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "compression.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
