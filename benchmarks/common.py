"""Shared benchmark harness utilities (timing, HLO inspection)."""
from __future__ import annotations

import re
import time

import jax
import numpy as np

# Per-rank payload sizes (float32 elements) shared by the communication
# benchmarks (bench_transports.py): 4 KiB latency-bound, 64 KiB mixed,
# 1 MiB bandwidth-bound.
PAYLOAD_SIZES = (1 << 10, 1 << 14, 1 << 18)

# --smoke sweep (the CI bench-smoke leg): one tiny payload — numbers are
# meaningless, but the artifact schema is identical, so schema drift is
# caught on every PR without paying real benchmark wall-clock.
SMOKE_PAYLOAD_SIZES = (1 << 8,)


def make_timer(smoke: bool):
    """time_fn, or its 1-warmup/1-rep smoke variant (still jit-compiled,
    so the staged program is exercised end-to-end)."""
    if not smoke:
        return time_fn
    return lambda fn, *args: time_fn(fn, *args, warmup=1, iters=1)


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall time (s) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def collective_ops(fn, *args):
    """Sorted list of collective op names staged by fn (lowered HLO)."""
    txt = jax.jit(fn).lower(*args).as_text()
    return sorted(
        re.findall(
            r"\b(all-gather|all-reduce|all-to-all|collective-permute|"
            r"reduce-scatter|collective-broadcast)\b",
            txt,
        )
    )


def csv_row(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}")
