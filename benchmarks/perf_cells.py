"""§Perf artifact runner: measure the hillclimbed cells baseline vs opt.

Usage: python benchmarks/perf_cells.py [--out benchmarks/artifacts/perf_cells.json]

Produces the before/after roofline terms backing EXPERIMENTS.md §Perf.
"""
import argparse
import json
import os
import subprocess
import sys

CELLS = [
    ("mamba2-370m", "train_4k"),
    ("smollm-360m", "train_4k"),
    ("qwen2-moe-a2.7b", "prefill_32k"),
    ("mistral-large-123b", "train_4k"),
]


def run_one(arch, shape, variant):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = os.path.join(os.path.dirname(__file__), "artifacts",
                       f"perf_{arch}_{shape}_{variant}.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--variant", variant, "--out", out],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    try:
        return json.load(open(out))[0]
    except Exception:
        return {"arch": arch, "shape": shape, "variant": variant,
                "status": "fail", "stderr": r.stderr[-500:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "artifacts", "perf_cells.json"))
    args = ap.parse_args()
    rows = []
    for arch, shape in CELLS:
        for variant in ("baseline", "opt"):
            rec = run_one(arch, shape, variant)
            rec["variant"] = variant
            rows.append(rec)
            if rec.get("status") == "ok":
                t = rec["roofline"]
                bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
                print(f"{arch} × {shape} [{variant}]: bound={bound:.2f}s "
                      f"(c={t['t_compute']:.2f} m={t['t_memory']:.2f} "
                      f"x={t['t_collective']:.2f})")
            else:
                print(f"{arch} × {shape} [{variant}]: FAIL")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
