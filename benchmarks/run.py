import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a roofline summary from
the dry-run artifacts when present).

  Fig. 8   -> bench_sample_sort      (zero-overhead sample sort)
  Fig. 10  -> bench_alltoall         (flat vs grid vs sparse exchange)
  Table I  -> bench_zero_overhead    (LOC + HLO parity + dispatch cost)
  Fig. 13  -> bench_reproducible     (p-invariant tree reduce)
  Fig. 11  -> bench_serialization    (serialized bcast)
  §V-A->EP -> bench_moe_dispatch     (MoE dispatch strategies)
"""
import json
import sys
import traceback

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _roofline_summary():
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    for mesh_name in ("pod16x16", "multipod2x16x16"):
        path = os.path.join(art, f"dryrun_{mesh_name}.json")
        if not os.path.exists(path):
            continue
        recs = json.load(open(path))
        ok = [r for r in recs if r["status"] == "ok"]
        print(f"# roofline[{mesh_name}]: {len(ok)} cells")
        for r in ok:
            t = r["roofline"]
            print(
                f"roofline_{mesh_name}_{r['arch']}_{r['shape']},"
                f"{max(t['t_compute'], t['t_memory'], t['t_collective'])*1e6:.1f},"
                f"dom={t['dominant']};useful={r['useful_flops_ratio']:.2f}"
            )


def main() -> None:
    import bench_sample_sort
    import bench_alltoall
    import bench_zero_overhead
    import bench_reproducible
    import bench_serialization
    import bench_moe_dispatch

    benches = [
        ("fig8_sample_sort", bench_sample_sort),
        ("fig10_alltoall", bench_alltoall),
        ("tableI_zero_overhead", bench_zero_overhead),
        ("fig13_reproducible", bench_reproducible),
        ("fig11_serialization", bench_serialization),
        ("moe_dispatch", bench_moe_dispatch),
    ]
    failures = []
    for name, mod in benches:
        print(f"# --- {name} ---")
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"{name},FAILED,{type(e).__name__}")
    _roofline_summary()
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
