"""xla vs pallas transport: allgather / reduce_scatter / allreduce.

Two comparisons per (op, payload) cell, over the payload sizes in
``benchmarks/common.py``:

* **SPMD level** — the table-generated collective under the vmap-as-SPMD
  interpreter at p=8, once per transport.  On CPU this times the staged
  semantics (ppermute ring vs XLA collective HLO), the transferable
  number being the *staged op mix*; on a TPU mesh the same code times
  the RDMA ring kernels against the XLA collectives.
* **Kernel level** — the stacked interpret-mode pallas kernel against
  the stacked NumPy-oracle-backed jnp reference, isolating kernel
  overhead from the transport plumbing.

Emits the standard report JSON (benchmarks/artifacts/transports.json)
plus csv_row lines for the console.  ``--smoke`` (the CI bench-smoke
leg) shrinks the sweep to one tiny payload at 1 rep — same artifact
schema, negligible wall-clock — and ``--out`` redirects the artifact so
the smoke run can be schema-diffed against the checked-in one
(benchmarks/check_artifacts.py).
"""
from __future__ import annotations

import argparse
import json
import operator
import os

import jax
import numpy as np

from common import PAYLOAD_SIZES, SMOKE_PAYLOAD_SIZES, csv_row, make_timer
from repro.core import Communicator, op, send_buf
from repro.kernels.collectives import (
    ring_allgather_stacked,
    ring_allreduce_stacked,
    ring_reduce_scatter_stacked,
)

P_RANKS = 8
TRANSPORTS = ("xla", "pallas")


def _spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def _ops(t, n):
    """(name, spmd callable, per-rank input) for payload of n elements."""
    chunk = max(1, n // P_RANKS)
    return (
        (
            "allgather",
            _spmd(lambda v: Communicator("x", transport=t).allgather(
                send_buf(v))),
            np.random.RandomState(0).randn(P_RANKS, chunk).astype(np.float32),
        ),
        (
            "reduce_scatter",
            _spmd(lambda v: Communicator("x", transport=t).reduce_scatter(
                send_buf(v), op(operator.add))),
            np.random.RandomState(1)
            .randn(P_RANKS, P_RANKS, chunk)
            .astype(np.float32),
        ),
        (
            "allreduce",
            _spmd(lambda v: Communicator("x", transport=t).allreduce(
                send_buf(v), op(operator.add))),
            np.random.RandomState(2).randn(P_RANKS, n).astype(np.float32),
        ),
    )


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    rows = []
    for n in (SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES):
        payload_bytes = n * 4
        for t in TRANSPORTS:
            for name, fn, x in _ops(t, n):
                us = time_fn(fn, x) * 1e6
                csv_row(
                    f"transport_{name}_{t}", us,
                    f"p={P_RANKS};payload_bytes={payload_bytes}",
                )
                rows.append(
                    {
                        "level": "spmd",
                        "op": name,
                        "transport": t,
                        "p": P_RANKS,
                        "payload_bytes": payload_bytes,
                        "us": us,
                    }
                )
        # kernel level: interpret-mode pallas vs jnp reference
        chunk = max(1, n // P_RANKS)
        ag_in = np.random.RandomState(3).randn(P_RANKS, chunk).astype(
            np.float32
        )
        rs_in = np.random.RandomState(4).randn(
            P_RANKS, P_RANKS, chunk
        ).astype(np.float32)
        ar_in = np.random.RandomState(5).randn(P_RANKS, n).astype(np.float32)
        for name, fn, x in (
            ("allgather", ring_allgather_stacked, ag_in),
            ("reduce_scatter", ring_reduce_scatter_stacked, rs_in),
            ("allreduce", ring_allreduce_stacked, ar_in),
        ):
            # The kernel variant is jitted (time_fn's contract) so the
            # timing excludes re-tracing; the ref variant is the plain
            # NumPy oracle baseline and runs as-is.
            variants = (
                ("pallas_kernel", jax.jit(lambda v, fn=fn: fn(v))),
                ("ref", lambda v, fn=fn: fn(v, force_ref=True)),
            )
            for variant, timed in variants:
                us = time_fn(timed, x) * 1e6
                csv_row(
                    f"kernel_{name}_{variant}", us,
                    f"p={P_RANKS};payload_bytes={payload_bytes}",
                )
                rows.append(
                    {
                        "level": "kernel",
                        "op": name,
                        "transport": variant,
                        "p": P_RANKS,
                        "payload_bytes": payload_bytes,
                        "us": us,
                    }
                )
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "transports.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
