"""Paper Fig. 10: all-to-all strategy comparison (flat / grid / sparse)
on BFS-frontier-like exchange patterns over three synthetic "graph
families" (mirroring Erdős–Rényi = global, RGG = local-neighbors, RHG =
mixed).  Reports wall time and *staged message count* — the startup-
latency proxy the grid/sparse algorithms optimize (on 8 CPU devices the
wall clock can't show ICI latency; the message counts + per-hop volumes
are the hardware-transferable result, and are also recorded from the
dry-run HLO for the 256-chip mesh)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import csv_row, time_fn
from repro.core import (
    Communicator,
    GridCommunicator,
    SparseAlltoall,
    neighbors,
    send_buf,
)

ROWS, COLS = 2, 4
P_RANKS = ROWS * COLS
CAP = 512
PAYLOAD = 16


def _mesh():
    return jax.make_mesh((ROWS, COLS), ("row", "col"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _frontier(family, rng):
    """(p, cap, payload) buckets per rank mimicking a BFS frontier."""
    x = rng.randn(P_RANKS, P_RANKS, CAP, PAYLOAD).astype(np.float32)
    if family == "rgg_local":  # only +-1 ring neighbors carry data
        mask = np.zeros((P_RANKS, P_RANKS))
        for r in range(P_RANKS):
            mask[r, (r + 1) % P_RANKS] = mask[r, (r - 1) % P_RANKS] = 1
        x *= mask[:, :, None, None]
    elif family == "rhg_mixed":  # ring + a few hubs
        mask = np.zeros((P_RANKS, P_RANKS))
        for r in range(P_RANKS):
            mask[r, (r + 1) % P_RANKS] = mask[r, (r - 1) % P_RANKS] = 1
            mask[r, 0] = 1
        x *= mask[:, :, None, None]
    return x  # erdos_renyi: dense


def _flat(x):
    return Communicator(("row", "col")).alltoall(send_buf(x))


def _grid(x):
    comm = Communicator(("row", "col")).extend(GridCommunicator)
    return comm.grid_alltoall(send_buf(x))


def _sparse_ring(x):
    comm = Communicator(("row", "col"))
    # ring neighborhood expressed as offsets; extract the 3 used buckets
    scomm = Communicator("col").extend(SparseAlltoall)  # degenerate demo
    return None  # handled in run() below


def run():
    mesh = _mesh()
    rng = np.random.RandomState(0)
    out = {}
    for family in ("erdos_renyi", "rgg_local", "rhg_mixed"):
        x = _frontier(family, rng).reshape(P_RANKS * P_RANKS, CAP, PAYLOAD)
        for name, fn in (("flat", _flat), ("grid", _grid)):
            jfn = jax.jit(jax.shard_map(
                fn, mesh=mesh, in_specs=P(("row", "col")),
                out_specs=P(("row", "col")), check_vma=False,
            ))
            t = time_fn(jfn, x)
            msgs = (P_RANKS - 1) if name == "flat" else (ROWS - 1) + (COLS - 1)
            vol = 1 if name == "flat" else 2
            csv_row(f"alltoall_{family}_{name}", t * 1e6,
                    f"msgs_per_rank={msgs};volume_x={vol}")
            out[(family, name)] = t

        if family != "erdos_renyi":
            # sparse: ring offsets only (the NBX insight — pay for 2
            # neighbors, not p-1)
            def sparse_fn(xb):
                comm = Communicator("flatranks").extend(SparseAlltoall)
                return comm.alltoallv_sparse(send_buf(xb), neighbors([1, -1]))

            mesh1 = jax.make_mesh((P_RANKS,), ("flatranks",),
                                  axis_types=(jax.sharding.AxisType.Auto,))
            xb = _frontier(family, rng)
            ring = np.stack(
                [np.stack([xb[r, (r + 1) % P_RANKS], xb[r, (r - 1) % P_RANKS]])
                 for r in range(P_RANKS)]
            ).reshape(P_RANKS * 2, CAP, PAYLOAD)
            jfn = jax.jit(jax.shard_map(
                sparse_fn, mesh=mesh1, in_specs=P("flatranks"),
                out_specs=P("flatranks"), check_vma=False,
            ))
            t = time_fn(jfn, ring)
            csv_row(f"alltoall_{family}_sparse", t * 1e6,
                    "msgs_per_rank=2;volume_x=0.25")
            out[(family, "sparse")] = t
    return out


if __name__ == "__main__":
    run()
