"""Bucketed-overlap gradient reduction vs the per-leaf allreduce loop.

Times the trainer's two manual-DP reduction strategies, distilled to the
reduction itself (a synthetic many-leaf gradient pytree under the
vmap-as-SPMD interpreter at p=8, once per transport — the same idiom as
bench_transports.py):

* ``allreduce`` — one table-generated ``allreduce`` per leaf (the
  pre-overlap trainer fast path);
* ``overlap``   — ``core/overlap.py``: RequestPool-scheduled bucketed
  reduction, swept over ``bucket_bytes`` × ``max_inflight`` ×
  per-bucket collective (``allreduce`` vs the ``reduce_scatter`` RS+AG
  decomposition), DESIGN.md §8.

On CPU the wall numbers characterize the *staged program* (HLO count
collapses from one collective per leaf to one per bucket — also
reported); on a TPU mesh the same code times real overlap.  Emits the
standard report JSON (benchmarks/artifacts/overlap.json) plus csv_row
lines for the console.
"""
from __future__ import annotations

import argparse
import json
import operator
import os

import jax
import numpy as np

from common import csv_row, make_timer
from repro.core import Communicator, op, overlap_reduce_tree, send_buf

P_RANKS = 8
TRANSPORTS = ("xla", "pallas")
# Gradient-tree shape: many small leaves + a few large ones, mimicking a
# transformer's bias/norm vs weight-matrix mix (sizes in f32 elements).
LEAF_SIZES = [64] * 24 + [4096] * 8 + [65536] * 4
BUCKET_BYTES = (1 << 14, 1 << 18, 1 << 22)
MAX_INFLIGHT = (1, 2, 4)
# --smoke: one cell per dimension, a toy tree — schema-identical rows.
SMOKE_LEAF_SIZES = [64] * 4 + [1024] * 2
SMOKE_BUCKET_BYTES = (1 << 12,)
SMOKE_MAX_INFLIGHT = (2,)


def make_tree(p, leaf_sizes=LEAF_SIZES):
    rng = np.random.RandomState(0)
    return {
        f"leaf{i:02d}": rng.randn(p, n).astype(np.float32)
        for i, n in enumerate(leaf_sizes)
    }


def leaf_allreduce(t):
    def f(tree):
        comm = Communicator("x", transport=t)
        inv_p = 1.0 / comm.size()
        return jax.tree.map(
            lambda g: comm.allreduce(send_buf(g), op(operator.add)) * inv_p,
            tree,
        )

    return f


def overlap(t, bucket_bytes, max_inflight, mode):
    def f(tree):
        comm = Communicator("x", transport=t)
        return overlap_reduce_tree(
            comm, tree, bucket_bytes=bucket_bytes,
            max_inflight=max_inflight, mode=mode,
            scale=1.0 / comm.size(),
        )

    return f


def spmd(f):
    return jax.jit(jax.vmap(f, axis_name="x"))


def collectives_issued(tree, bucket_bytes=None, mode="allreduce"):
    """Collectives each strategy issues — the schedule-shape number that
    transfers to real meshes (under the vmap interpreter collectives
    don't lower to collective HLOs, so this is computed analytically:
    one per leaf for the baseline, one per bucket — two for the RS+AG
    decomposition — for the overlap engine)."""
    from repro.core import plan_buckets

    n_leaves = len(jax.tree.leaves(tree))
    if bucket_bytes is None:
        return n_leaves
    # per-rank leaves: strip the stacked p dim the SPMD harness adds
    leaves = [v[0] for v in jax.tree.leaves(tree)]
    n_buckets = len(plan_buckets(leaves, bucket_bytes))
    return n_buckets * (2 if mode == "reduce_scatter" else 1)


def run(smoke: bool = False, out: str | None = None):
    time_fn = make_timer(smoke)
    bucket_bytes = SMOKE_BUCKET_BYTES if smoke else BUCKET_BYTES
    max_inflight = SMOKE_MAX_INFLIGHT if smoke else MAX_INFLIGHT
    rows = []
    tree = make_tree(P_RANKS, SMOKE_LEAF_SIZES if smoke else LEAF_SIZES)
    total_bytes = sum(v.nbytes // P_RANKS for v in tree.values())
    for t in TRANSPORTS:
        base = leaf_allreduce(t)
        us = time_fn(spmd(base), tree) * 1e6
        n_ops = collectives_issued(tree)
        csv_row(f"grad_reduce_allreduce_{t}", us,
                f"p={P_RANKS};bytes={total_bytes};ops={n_ops}")
        rows.append({
            "strategy": "allreduce", "transport": t, "p": P_RANKS,
            "grad_bytes": total_bytes, "bucket_bytes": None,
            "max_inflight": None, "mode": None, "us": us,
            "collectives_issued": n_ops,
        })
        for mode in ("allreduce", "reduce_scatter"):
            for bb in bucket_bytes:
                for infl in max_inflight:
                    fn = overlap(t, bb, infl, mode)
                    us = time_fn(spmd(fn), tree) * 1e6
                    n_ops = collectives_issued(tree, bb, mode)
                    csv_row(
                        f"grad_reduce_overlap_{mode}_{t}", us,
                        f"p={P_RANKS};bytes={total_bytes};"
                        f"bucket_bytes={bb};max_inflight={infl};"
                        f"ops={n_ops}",
                    )
                    rows.append({
                        "strategy": "overlap", "transport": t,
                        "p": P_RANKS, "grad_bytes": total_bytes,
                        "bucket_bytes": bb, "max_inflight": infl,
                        "mode": mode, "us": us,
                        "collectives_issued": n_ops,
                    })
    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "overlap.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tree, 1 rep (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
