"""Elastic-training costs (DESIGN.md §15): checkpoint stall + recovery.

Two measurements back the §15 contracts:

* **checkpoint stall** — the wall time a training step pays for
  ``CheckpointManager.save``: the sync path blocks until the snapshot is
  durable; the async path pays only the device→host copy + enqueue (the
  writer thread owns the disk).  The full run *asserts* the non-stall
  contract (async < sync) and records it per row
  (``async_nonstall``).
* **recovery latency vs shrink size** — the ULFM recovery sequence
  (``WorldComm.shrink`` → ``survivor_groups`` → ``rederive_transport``
  → sharded restore with the EF-residual fold) timed end-to-end for
  p 8→4 and 4→2.

On CPU the wall numbers characterize the host/IO path (there is no real
fleet); the artifact schema is what CI gates.  ``--smoke``/``--out``
follow the bench-smoke conventions (tiny payload, few reps,
schema-identical rows).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from common import PAYLOAD_SIZES, SMOKE_PAYLOAD_SIZES, csv_row
from repro.checkpoint.manager import CheckpointManager
from repro.core.compression import reshard_error_feedback
from repro.core.ulfm import WorldComm

SHRINKS = ((8, 4), (4, 2))


class _Dev:
    """Fake device for the shrink-latency measurement (only .id is read)."""

    def __init__(self, i):
        self.id = i


def _tree_of(n):
    """A params-like pytree totalling ~n float32 elements."""
    rng = np.random.RandomState(0)
    half = max(n // 2, 1)
    return {
        "w": rng.randn(half).astype(np.float32),
        "b": rng.randn(half).astype(np.float32),
    }


def _median_save_stall(ckpt, tree, async_, iters):
    """Median wall seconds the CALLER spends inside save() — the per-step
    stall.  The writer queue is drained outside the timed region so each
    measurement starts from an idle writer."""
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        ckpt.save(1000 + i, tree, async_=async_)
        ts.append(time.perf_counter() - t0)
        ckpt.wait()
    return float(np.median(ts))


def run(smoke: bool = False, out: str | None = None):
    iters = 3 if smoke else 10
    rows = []

    # -- checkpoint stall: sync vs async --------------------------------
    for n in (SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES):
        payload_bytes = n * 4
        tree = _tree_of(n)
        d = tempfile.mkdtemp(prefix="bench_elastic_")
        try:
            ckpt = CheckpointManager(d, keep=2)
            sync_s = _median_save_stall(ckpt, tree, False, iters)
            async_s = _median_save_stall(ckpt, tree, True, iters)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        nonstall = bool(async_s < sync_s)
        if not smoke:
            # the §15 non-stall contract: an async save costs the step
            # only the host copy, never the disk write
            assert nonstall, (
                f"async save stalled {async_s*1e6:.0f}us >= sync "
                f"{sync_s*1e6:.0f}us at {payload_bytes} bytes"
            )
        for variant, stall in (("sync", sync_s), ("async", async_s)):
            csv_row(
                f"elastic_ckpt_{variant}_n{n}", stall * 1e6,
                f"payload_bytes={payload_bytes};iters={iters}",
            )
            rows.append({
                "mode": "ckpt-save", "variant": variant,
                "p_from": None, "p_to": None,
                "payload_bytes": payload_bytes, "us": stall * 1e6,
                "async_nonstall": nonstall if variant == "async" else None,
            })

    # -- recovery latency vs shrink size ---------------------------------
    n = (SMOKE_PAYLOAD_SIZES if smoke else PAYLOAD_SIZES)[-1]
    for p_from, p_to in SHRINKS:
        err = np.random.RandomState(1).randn(p_from, n).astype(np.float32)
        d = tempfile.mkdtemp(prefix="bench_elastic_")
        try:
            ckpt = CheckpointManager(d, keep=2)
            ckpt.save(4, {"params": _tree_of(n), "extra": err},
                      extra_meta={"world_size": p_from})
            world = WorldComm([_Dev(i) for i in range(p_from)])

            def recover():
                nw = world.shrink(list(range(p_to, p_from)))
                nw.survivor_groups()
                nw.rederive_transport("hier")
                return ckpt.restore(4, reshard=lambda t, m: {
                    "params": t["params"],
                    "extra": reshard_error_feedback(
                        t["extra"], m["extra"]["world_size"], p_to
                    ),
                })

            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                tree_got, _ = recover()
                ts.append(time.perf_counter() - t0)
            assert tree_got["extra"].shape[0] == p_to
            us = float(np.median(ts)) * 1e6
        finally:
            shutil.rmtree(d, ignore_errors=True)
        csv_row(
            f"elastic_recovery_{p_from}to{p_to}", us,
            f"payload_bytes={n * 4};iters={iters}",
        )
        rows.append({
            "mode": "recovery", "variant": None,
            "p_from": p_from, "p_to": p_to,
            "payload_bytes": n * 4, "us": us,
            "async_nonstall": None,
        })

    out_path = out or os.path.join(
        os.path.dirname(__file__), "artifacts", "elastic.json"
    )
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny payloads, few reps (CI schema check)")
    ap.add_argument("--out", default=None, help="artifact path override")
    a = ap.parse_args()
    run(smoke=a.smoke, out=a.out)
