#!/usr/bin/env python
"""Benchmark-artifact schema gate (the CI bench-smoke leg).

The benchmarks emit JSON artifacts (a list of flat row dicts) that the
report tooling and EXPERIMENTS notes consume; a refactor that silently
renames or drops a key rots every downstream consumer.  This checker
diffs a freshly-emitted artifact (typically a ``--smoke`` run: tiny
payloads, 1 rep, schema-identical rows) against the checked-in
reference in ``benchmarks/artifacts/`` and fails on **schema drift**:

* top-level shape (must be a list of objects),
* the per-file key set (union over rows) — missing *or* novel keys fail,
* per-key value kinds (number / string / bool / null) — a key that was
  numeric in the reference may not become a string, etc.  ``null`` is
  always admissible alongside its reference kinds (optional cells).

Row *counts* and *values* are not compared — smoke runs sweep fewer
cells on purpose.

Usage:
    python benchmarks/check_artifacts.py --ref benchmarks/artifacts \\
        --got smoke-artifacts [name.json ...]

Without explicit names, every ``*.json`` present in ``--ref`` is
checked (so adding a new benchmark artifact automatically extends the
gate once its reference is committed).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _kinds(value) -> str:
    if value is None:
        return "null"
    if isinstance(value, bool):
        return "bool"
    if isinstance(value, (int, float)):
        return "number"
    if isinstance(value, str):
        return "string"
    if isinstance(value, list):
        return "array"
    return "object"


def _schema(rows):
    """{key: set of value kinds} over all rows; raises on wrong shape."""
    if not isinstance(rows, list) or not rows:
        raise ValueError("artifact must be a non-empty JSON list of rows")
    schema = {}
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise ValueError(f"row {i} is {type(row).__name__}, not object")
        for k, v in row.items():
            schema.setdefault(k, set()).add(_kinds(v))
    return schema


def check_file(ref_path: str, got_path: str) -> list:
    """Returns a list of human-readable drift messages (empty = clean)."""
    problems = []
    with open(ref_path) as f:
        ref = json.load(f)
    if not os.path.exists(got_path):
        return [f"missing emitted artifact: {got_path}"]
    with open(got_path) as f:
        got = json.load(f)
    try:
        ref_schema = _schema(ref)
    except ValueError as e:
        return [f"reference {ref_path} is malformed: {e}"]
    try:
        got_schema = _schema(got)
    except ValueError as e:
        return [f"{got_path}: {e}"]

    missing = sorted(set(ref_schema) - set(got_schema))
    novel = sorted(set(got_schema) - set(ref_schema))
    if missing:
        problems.append(f"keys dropped: {missing}")
    if novel:
        problems.append(
            f"keys added: {novel} (update the checked-in reference "
            f"artifact if intentional)"
        )
    for k in sorted(set(ref_schema) & set(got_schema)):
        allowed = ref_schema[k] | {"null"}
        bad = got_schema[k] - allowed
        if bad:
            problems.append(
                f"key {k!r}: value kind(s) {sorted(bad)} not in the "
                f"reference kinds {sorted(ref_schema[k])}"
            )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "artifacts"))
    ap.add_argument("--got", required=True,
                    help="directory holding the freshly-emitted artifacts")
    ap.add_argument("names", nargs="*",
                    help="artifact file names (default: every *.json "
                         "in --ref)")
    args = ap.parse_args(argv)

    names = args.names or sorted(
        f for f in os.listdir(args.ref) if f.endswith(".json")
    )
    if not names:
        print(f"no reference artifacts found in {args.ref}")
        return 1
    failed = False
    for name in names:
        problems = check_file(
            os.path.join(args.ref, name), os.path.join(args.got, name)
        )
        if problems:
            failed = True
            print(f"SCHEMA DRIFT in {name}:")
            for p in problems:
                print(f"  - {p}")
        else:
            print(f"{name}: schema OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
