"""Paper Table I + the (near) zero-overhead claim.

Three measurements:
1. HLO parity — the KaMPIng-style call stages exactly the collectives a
   hand-rolled implementation would (the paper validated this with the
   MPI profiling interface; XLA's lowered HLO is our PMPI).
2. Dispatch (trace-time) overhead — cost of the named-parameter layer at
   staging time; amortized to zero by jit caching.
3. Lines of code for the vector-allgather example (Table I row 1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from common import csv_row
from repro.core import Communicator, send_buf

P_RANKS = 8


def run():
    mesh = jax.make_mesh((P_RANKS,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def kamping(v):
        return Communicator("x").allgatherv(send_buf(v))

    def handrolled(v):
        return jax.lax.all_gather(v, "x", tiled=True)

    xs = jax.ShapeDtypeStruct((P_RANKS * 64, 32), jnp.float32)

    import re

    def colls(fn):
        txt = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=P("x"), out_specs=P(None),
            check_vma=False)).lower(xs).as_text()
        return re.findall(
            r"\b(all-gather|all-reduce|all-to-all|collective-permute)\b", txt
        )

    parity = colls(kamping) == colls(handrolled)
    csv_row("zero_overhead_hlo_parity", 0.0, f"identical_collectives={parity}")
    assert parity, "KaMPIng call stages different collectives!"

    # trace-time dispatch cost (retrace both, compare)
    def trace_time(fn):
        sm = jax.shard_map(fn, mesh=mesh, in_specs=P("x"),
                           out_specs=P(None), check_vma=False)
        t0 = time.perf_counter()
        for _ in range(20):
            jax.make_jaxpr(sm)(xs)
        return (time.perf_counter() - t0) / 20

    tk, th = trace_time(kamping), trace_time(handrolled)
    csv_row("dispatch_overhead_kamping_us", tk * 1e6, "trace_time")
    csv_row("dispatch_overhead_handrolled_us", th * 1e6, "trace_time")
    csv_row("dispatch_overhead_delta_us", (tk - th) * 1e6,
            "amortized_to_zero_by_jit_cache")

    # Table I: LOC of the two vector-allgather implementations in
    # examples/quickstart.py (version1 = 2 lines, handrolled = 6 lines)
    import inspect
    import examples_loc  # counts from the example file

    counts = examples_loc.loc_table()
    for impl, loc in counts.items():
        csv_row(f"loc_vector_allgather_{impl}", loc, "tableI")
    return {"parity": parity, "trace_kamping": tk, "trace_handrolled": th,
            "loc": counts}


if __name__ == "__main__":
    run()
