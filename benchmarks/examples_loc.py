"""Table I LOC accounting: count the executable lines of each
implementation variant in examples/quickstart.py and examples/bfs.py."""
from __future__ import annotations

import os
import re

_EX = os.path.join(os.path.dirname(__file__), "..", "examples")


def _body_lines(path, fn_name):
    src = open(path).read()
    m = re.search(rf"def {fn_name}\(.*?\):\n((?:    .*\n|\n)+)", src)
    if not m:
        return 0
    lines = [
        l for l in m.group(1).splitlines()
        if l.strip() and not l.strip().startswith("#")
    ]
    return len(lines)


def loc_table():
    q = os.path.join(_EX, "quickstart.py")
    return {
        "kamping_oneliner": _body_lines(q, "version1"),
        "kamping_explicit": _body_lines(q, "version2"),
        "handrolled": _body_lines(q, "handrolled"),
    }


if __name__ == "__main__":
    print(loc_table())
